//! Reduced-precision float codecs used for KV-cache *storage*.
//!
//! SWAN stores sparse values as float16 (default, Eq. 1: 3k+2 bytes/vector)
//! or as 8-bit E4M3 floats (aggressive mode, 2k+2 bytes/vector).  Compute
//! always happens in f32 after a dequantize-on-read; these codecs define
//! exactly what information survives storage.

/// Convert an f32 to IEEE binary16 bits (round-to-nearest-even).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x7f_ffff;

    if exp == 0xff {
        // inf / nan
        let m = if mant != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | m;
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if e <= 0 {
        // subnormal or zero
        if e < -10 {
            return sign;
        }
        let m = mant | 0x80_0000;
        let shift = (14 - e) as u32;
        let half = m >> shift;
        // round to nearest even
        let rem = m & ((1 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded = if rem > halfway || (rem == halfway && (half & 1) == 1) {
            half + 1
        } else {
            half
        };
        return sign | rounded as u16;
    }
    let half = (e as u32) << 10 | (mant >> 13);
    let rem = mant & 0x1fff;
    let rounded = if rem > 0x1000 || (rem == 0x1000 && (half & 1) == 1) {
        half + 1 // may carry into exponent; that is correct rounding
    } else {
        half
    };
    sign | rounded as u16
}

/// Convert IEEE binary16 bits to f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: normalise
            let mut e = -1i32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3ff;
            sign | (((127 - 15 + e + 2) as u32) << 23) | (m << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// Round-trip f32 through f16 (storage precision of the 16-bit variant).
pub fn quantize_f16(x: f32) -> f32 {
    f16_bits_to_f32(f32_to_f16_bits(x))
}

/// FP8 E4M3 (1 sign, 4 exponent, 3 mantissa; bias 7; max finite 448,
/// matching the OCP FP8 spec without NaN-overloading subtleties —
/// out-of-range values saturate).
pub fn f32_to_fp8_e4m3(x: f32) -> u8 {
    if x.is_nan() {
        return 0x7f;
    }
    let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
    let a = x.abs();
    if a == 0.0 {
        return sign;
    }
    if a >= 448.0 {
        return sign | 0x7e; // saturate to max finite 448
    }
    // smallest subnormal = 2^-9
    if a < 2.0_f32.powi(-9) * 0.5 {
        return sign;
    }
    let bits = a.to_bits();
    let mut e = ((bits >> 23) & 0xff) as i32 - 127;
    let mant = bits & 0x7f_ffff;
    if e < -6 {
        // subnormal range: value = m * 2^-9, m in 1..7
        let m = (a / 2.0_f32.powi(-9)).round() as u32;
        if m == 0 {
            return sign;
        }
        if m >= 8 {
            return sign | (1 << 3); // rounds up into the normal range
        }
        return sign | m as u8;
    }
    // normal: round the 3-bit mantissa
    let mut m3 = (mant >> 20) as u32;
    let rem = mant & 0xf_ffff;
    let halfway = 0x8_0000;
    if rem > halfway || (rem == halfway && (m3 & 1) == 1) {
        m3 += 1;
        if m3 == 8 {
            m3 = 0;
            e += 1;
            if e > 8 {
                return sign | 0x7e;
            }
        }
    }
    sign | (((e + 7) as u8) << 3) | m3 as u8
}

/// FP8 E4M3 bits to f32.
pub fn fp8_e4m3_to_f32(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let exp = ((b >> 3) & 0x0f) as i32;
    let mant = (b & 0x7) as f32;
    if exp == 0 {
        sign * mant * 2.0_f32.powi(-9)
    } else {
        sign * (1.0 + mant / 8.0) * 2.0_f32.powi(exp - 7)
    }
}

/// Round-trip f32 through FP8 E4M3 (storage precision of the 8-bit variant).
pub fn quantize_fp8(x: f32) -> f32 {
    fp8_e4m3_to_f32(f32_to_fp8_e4m3(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_exact_values() {
        for &x in &[0.0f32, 1.0, -1.0, 0.5, 2.0, 65504.0, -0.25, 1024.0] {
            assert_eq!(quantize_f16(x), x, "{x}");
        }
    }

    #[test]
    fn f16_relative_error_bounded() {
        let mut r = crate::util::Pcg64::new(1);
        for _ in 0..10_000 {
            let x = (r.normal_f32()) * 10.0;
            let q = quantize_f16(x);
            let rel = ((q - x) / x.abs().max(1e-6)).abs();
            assert!(rel < 1e-3 || x.abs() < 1e-4, "x={x} q={q}");
        }
    }

    #[test]
    fn f16_overflow_to_inf() {
        assert!(f16_bits_to_f32(f32_to_f16_bits(1e6)).is_infinite());
    }

    #[test]
    fn f16_subnormals() {
        let tiny = 3.0e-6f32; // subnormal range of f16
        let q = quantize_f16(tiny);
        assert!((q - tiny).abs() / tiny < 0.1, "tiny={tiny} q={q}");
    }

    #[test]
    fn fp8_exact_values() {
        for &x in &[0.0f32, 1.0, -1.0, 0.5, 2.0, 448.0, -448.0, 0.125] {
            assert_eq!(quantize_fp8(x), x, "{x}");
        }
    }

    #[test]
    fn fp8_saturates() {
        assert_eq!(quantize_fp8(1e9), 448.0);
        assert_eq!(quantize_fp8(-1e9), -448.0);
    }

    #[test]
    fn fp8_relative_error_bounded() {
        let mut r = crate::util::Pcg64::new(2);
        for _ in 0..10_000 {
            let x = r.normal_f32() * 4.0;
            if x.abs() < 0.015625 {
                // subnormal range: absolute (not relative) error bound applies
                continue;
            }
            let q = quantize_fp8(x);
            let rel = ((q - x) / x).abs();
            assert!(rel <= 0.0625 + 1e-6, "x={x} q={q} rel={rel}");
        }
    }

    #[test]
    fn fp8_monotonic() {
        let mut last = -f32::INFINITY;
        for b in 0..0x7f {
            // positive codes ascending
            let v = fp8_e4m3_to_f32(b);
            assert!(v >= last, "code {b}");
            last = v;
        }
    }

    #[test]
    fn fp8_roundtrip_idempotent() {
        let mut r = crate::util::Pcg64::new(3);
        for _ in 0..1000 {
            let x = r.normal_f32() * 100.0;
            let q = quantize_fp8(x);
            assert_eq!(quantize_fp8(q), q);
        }
    }
}
