//! Small self-contained utilities (the sandbox has no external crates for
//! these: rng, half/8-bit float codecs, statistics, JSON).

pub mod fp;
pub mod json;
pub mod rng;
pub mod stats;
pub mod sync;

pub use fp::{f16_bits_to_f32, f32_to_f16_bits, f32_to_fp8_e4m3, fp8_e4m3_to_f32};
pub use rng::Pcg64;
pub use stats::Summary;
pub use sync::{lock_recover, read_recover, wait_recover, write_recover};
