//! PCG-64 (XSL-RR) deterministic random generator.
//!
//! Used everywhere randomness is needed (workload generation, property
//! tests, random-projection ablation) so that every experiment in
//! EXPERIMENTS.md is exactly reproducible.

/// PCG XSL-RR 128/64 generator (the same family numpy's `default_rng`
/// uses; we do not need stream-compatibility with numpy, only determinism).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams for practical purposes.
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg64 {
            state: (seed as u128).wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1,
            inc: ((seed as u128) << 1) | 1,
        };
        // warm up
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Derive an independent child generator (for per-request / per-case
    /// streams).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        Pcg64::new(self.next_u64() ^ tag.wrapping_mul(0xa076_1d64_78bd_642f))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi) (integers).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-12 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a vec with standard-normal f32s.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Pcg64::new(3);
        for _ in 0..10_000 {
            let x = r.below(7);
            assert!(x < 7);
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Pcg64::new(4);
        let n = 50_000;
        let mean = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(6);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
