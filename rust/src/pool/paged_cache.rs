//! Algorithm 1 storage over pool blocks: paged sparse rows, paged dense
//! ring, and the [`PagedHybridCache`] / [`PagedSwanCache`] drop-ins.
//!
//! Bit-identity contract: every row lands in the same order, through the
//! same winnow ([`crate::sparse::winnow_into`]) and the same kernels, as
//! the contiguous [`HybridCache`](crate::swan::HybridCache) path.  The
//! per-block score walk folds per-block running maxima with `max` (exact
//! and order-insensitive), and the per-block scatter-add visits rows in
//! the same global order — so attention outputs match the contiguous
//! layout to the bit (`tests/pool.rs`).

// lint: allow(indexing, "block/slot arithmetic (r / block_tokens, r % block_tokens) over this cache's own row count cannot leave the table; the CSR walk is the decode hot path, where a bounds-checked accessor chain would cost real latency, and tests/pool.rs locks bit-identity against the contiguous path")

use std::sync::Arc;

use crate::kvcache::CachePolicy;
use crate::simd::Kernels;
use crate::sparse::{winnow_into, StorageMode};
use crate::swan::attention::{swan_attend, SwanAttendable};
use crate::swan::batch::AttentionScratch;
use crate::swan::hybrid_cache::SwanParams;

use super::{BlockGeometry, BlockPool, BlockTable};

/// One sparse stream (the paged analogue of
/// [`crate::sparse::SparseStore`]): winnowed CSR rows packed
/// `block_tokens` to a block, appended through the shared
/// [`winnow_into`] so quantization and lane padding are identical to the
/// contiguous store.  `bytes` accounting charges per-row *real* nnz
/// (Eq. 1), accumulated block by block.
pub struct PagedRows {
    table: BlockTable,
    geo: BlockGeometry,
    rows: usize,
}

impl PagedRows {
    pub fn new(pool: Arc<BlockPool>, geo: BlockGeometry) -> PagedRows {
        PagedRows { table: BlockTable::new(pool), geo, rows: 0 }
    }

    /// Winnow one dense row into the tail block (leasing a fresh block at
    /// every `block_tokens` boundary).
    pub fn push_pruned(&mut self, dense: &[f32], k: usize, mode: StorageMode) {
        let bt = self.geo.block_tokens;
        if self.rows % bt == 0 {
            let cap = self.geo.sparse_float_capacity();
            let b = self.table.push_block();
            b.vals.reserve(cap);
            b.idx.reserve(cap);
            b.offsets.reserve(bt);
            b.nnz.reserve(bt);
        }
        // lint: allow(panic, "the block-boundary branch above guarantees a tail block exists by the time any row is appended")
        let b = self.table.last_mut().unwrap();
        let nnz = winnow_into(dense, k, mode, self.geo.lanes, &mut b.vals, &mut b.idx);
        b.offsets.push(b.vals.len() as u32);
        b.nnz.push(nnz as u32);
        b.bytes += mode.vector_bytes(nnz);
        self.rows += 1;
    }

    /// Rows stored across all blocks.
    pub fn len(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Real (unpadded) nnz of row `r`.
    pub fn nnz(&self, r: usize) -> usize {
        let bt = self.geo.block_tokens;
        self.table.blocks()[r / bt].nnz[r % bt] as usize
    }

    /// Live `(vals, idx)` entries of row `r` (padding excluded), for
    /// tests and reconstruction.
    pub fn row(&self, r: usize) -> (&[f32], &[u16]) {
        let bt = self.geo.block_tokens;
        let b = &self.table.blocks()[r / bt];
        let local = r % bt;
        let start = b.offsets[local] as usize;
        let live = b.nnz[local] as usize;
        (&b.vals[start..start + live], &b.idx[start..start + live])
    }

    /// Accounted (Eq. 1) bytes — per-block real-nnz sums.
    pub fn storage_bytes(&self) -> usize {
        self.table.total_bytes()
    }

    /// The stream's block-table row (pool block ids in order).
    pub fn block_ids(&self) -> Vec<u32> {
        self.table.block_ids()
    }

    /// Blocks currently leased by this stream.
    pub fn block_count(&self) -> usize {
        self.table.len()
    }

    /// Fused CSR scores + running max across all blocks, oldest row
    /// first; one score pushed per row.  Per-block maxima fold with
    /// `max`, which equals the contiguous store's single-pass max.
    pub fn scores_max_into_with(
        &self,
        ks: Kernels,
        q: &[f32],
        scale: f32,
        out: &mut Vec<f32>,
    ) -> f32 {
        let mut m = f32::NEG_INFINITY;
        for b in self.table.blocks() {
            let mb = ks.csr_scores_max_into(&b.vals, &b.idx, &b.offsets, scale, q, out);
            m = m.max(mb);
        }
        m
    }

    /// Weighted scatter-add of every row (`out += Σ w[r] * row_r`),
    /// slicing `w` block by block in global row order.
    pub fn axpy_all_with(&self, ks: Kernels, w: &[f32], out: &mut [f32]) {
        debug_assert_eq!(w.len(), self.rows);
        let mut r = 0;
        for b in self.table.blocks() {
            let n = b.rows();
            ks.csr_axpy_all(&b.vals, &b.idx, &b.offsets, &w[r..r + n], out);
            r += n;
        }
    }
}

/// The dense recency ring's slot array, paged: `ceil(cap / block_tokens)`
/// blocks leased up front, each holding `block_tokens` rows of `d_head`
/// floats in `vals`.  Pure storage — FIFO state (`head`, `buf_len`) lives
/// on [`PagedHybridCache`], shared by the key and value rings exactly as
/// in the contiguous cache.
pub struct PagedRing {
    table: BlockTable,
    geo: BlockGeometry,
}

impl PagedRing {
    pub fn new(pool: Arc<BlockPool>, geo: BlockGeometry, cap: usize) -> PagedRing {
        let mut table = BlockTable::new(pool);
        let floats = geo.dense_floats();
        for _ in 0..cap.div_ceil(geo.block_tokens) {
            let b = table.push_block();
            b.vals.resize(floats, 0.0);
        }
        PagedRing { table, geo }
    }

    pub fn row(&self, slot: usize) -> &[f32] {
        let bt = self.geo.block_tokens;
        let d = self.geo.d_head;
        let off = (slot % bt) * d;
        &self.table.blocks()[slot / bt].vals[off..off + d]
    }

    pub fn row_mut(&mut self, slot: usize) -> &mut [f32] {
        let bt = self.geo.block_tokens;
        let d = self.geo.d_head;
        let off = (slot % bt) * d;
        &mut self.table.get_mut(slot / bt).vals[off..off + d]
    }

    pub fn block_count(&self) -> usize {
        self.table.len()
    }
}

/// The hybrid cache of Algorithm 1 over pool blocks — same FIFO
/// semantics, same winnow, same accounting as
/// [`crate::swan::HybridCache`], but every byte lives in fixed-size
/// leased blocks so sequences can be preempted and admitted at block
/// granularity.  One instance serves one (layer, kv-head) pair of one
/// sequence; all four streams (k/v × sparse/ring) lease from the same
/// pool.
pub struct PagedHybridCache {
    pub params: SwanParams,
    d_h: usize,
    pub k_sparse: PagedRows,
    pub v_sparse: PagedRows,
    k_ring: PagedRing,
    v_ring: PagedRing,
    /// Ring slot of the oldest live row (0 when empty).
    head: usize,
    buf_len: usize,
}

impl PagedHybridCache {
    pub fn new(
        d_h: usize,
        params: SwanParams,
        block_tokens: usize,
        pool: Arc<BlockPool>,
    ) -> PagedHybridCache {
        let mut params = params;
        params.lanes = params.resolved_lanes();
        let geo = BlockGeometry::new(block_tokens, d_h, params.lanes);
        PagedHybridCache {
            params,
            d_h,
            k_sparse: PagedRows::new(pool.clone(), geo),
            v_sparse: PagedRows::new(pool.clone(), geo),
            k_ring: PagedRing::new(pool.clone(), geo, params.buffer),
            v_ring: PagedRing::new(pool, geo, params.buffer),
            head: 0,
            buf_len: 0,
        }
    }

    pub fn d_h(&self) -> usize {
        self.d_h
    }

    pub fn buffer_len(&self) -> usize {
        self.buf_len
    }

    pub fn sparse_len(&self) -> usize {
        self.k_sparse.len()
    }

    pub fn len(&self) -> usize {
        self.buf_len + self.k_sparse.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocks this cache currently leases (all four streams).
    pub fn leased_blocks(&self) -> usize {
        self.k_sparse.block_count()
            + self.v_sparse.block_count()
            + self.k_ring.block_count()
            + self.v_ring.block_count()
    }

    pub fn set_k_active(&mut self, k_keys: usize, k_vals: usize) {
        self.params.k_active_keys = k_keys.min(self.d_h);
        self.params.k_active_vals = k_vals.min(self.d_h);
    }

    /// Mirror of [`crate::swan::HybridCache::append`]: fill the ring,
    /// winnow the oldest row out on overflow.
    pub fn append(&mut self, k_hat: &[f32], v_hat: &[f32]) {
        debug_assert_eq!(k_hat.len(), self.d_h);
        debug_assert_eq!(v_hat.len(), self.d_h);
        let cap = self.params.buffer;
        if cap == 0 {
            self.k_sparse.push_pruned(k_hat, self.params.k_active_keys, self.params.mode);
            self.v_sparse.push_pruned(v_hat, self.params.k_active_vals, self.params.mode);
            return;
        }
        if self.buf_len == cap {
            self.evict_oldest();
        }
        let slot = (self.head + self.buf_len) % cap;
        self.k_ring.row_mut(slot).copy_from_slice(k_hat);
        self.v_ring.row_mut(slot).copy_from_slice(v_hat);
        self.buf_len += 1;
    }

    fn evict_oldest(&mut self) {
        debug_assert!(self.buf_len > 0);
        self.k_sparse.push_pruned(
            self.k_ring.row(self.head),
            self.params.k_active_keys,
            self.params.mode,
        );
        self.v_sparse.push_pruned(
            self.v_ring.row(self.head),
            self.params.k_active_vals,
            self.params.mode,
        );
        self.head = (self.head + 1) % self.params.buffer;
        self.buf_len -= 1;
    }

    /// Mirror of [`crate::swan::HybridCache::load_prefill`]: spill
    /// existing ring rows FIFO, winnow the incoming head straight to
    /// sparse, copy the tail into ring slots.
    pub fn load_prefill(&mut self, k_hats: &[f32], v_hats: &[f32]) {
        let d = self.d_h;
        let n = k_hats.len() / d;
        debug_assert_eq!(k_hats.len(), n * d);
        debug_assert_eq!(v_hats.len(), n * d);
        let cap = self.params.buffer;
        let spill = (self.buf_len + n).saturating_sub(cap);
        let spill_old = spill.min(self.buf_len);
        for _ in 0..spill_old {
            self.evict_oldest();
        }
        let spill_new = spill - spill_old;
        for t in 0..spill_new {
            self.k_sparse.push_pruned(
                &k_hats[t * d..(t + 1) * d],
                self.params.k_active_keys,
                self.params.mode,
            );
            self.v_sparse.push_pruned(
                &v_hats[t * d..(t + 1) * d],
                self.params.k_active_vals,
                self.params.mode,
            );
        }
        for t in spill_new..n {
            let slot = (self.head + self.buf_len) % cap;
            self.k_ring.row_mut(slot).copy_from_slice(&k_hats[t * d..(t + 1) * d]);
            self.v_ring.row_mut(slot).copy_from_slice(&v_hats[t * d..(t + 1) * d]);
            self.buf_len += 1;
        }
    }

    /// Serving-accounting bytes: per-block real-nnz Eq. 1 sums for the
    /// sparse streams, the f16 convention for live ring rows — the same
    /// total the contiguous cache reports.
    pub fn storage_bytes(&self) -> usize {
        let sparse = self.k_sparse.storage_bytes() + self.v_sparse.storage_bytes();
        let dense = 2 * self.buf_len * self.d_h * 2; // k+v, f16
        sparse + dense
    }

    pub fn dense_equiv_bytes(&self) -> usize {
        2 * self.len() * self.d_h * 2
    }

    /// Read-only attention via the shared generic walk.
    pub fn attend(
        &self,
        q_hat: &[f32],
        k_hat_cur: &[f32],
        v_hat_cur: &[f32],
        scores: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        swan_attend(q_hat, self, k_hat_cur, v_hat_cur, scores, out);
    }
}

impl SwanAttendable for PagedHybridCache {
    fn d_h(&self) -> usize {
        PagedHybridCache::d_h(self)
    }

    fn sparse_len(&self) -> usize {
        PagedHybridCache::sparse_len(self)
    }

    fn buffer_len(&self) -> usize {
        PagedHybridCache::buffer_len(self)
    }

    fn k_scores_max_into(&self, ks: Kernels, q: &[f32], scale: f32, out: &mut Vec<f32>) -> f32 {
        self.k_sparse.scores_max_into_with(ks, q, scale, out)
    }

    fn for_each_ring_k(&self, mut f: impl FnMut(&[f32])) {
        let cap = self.params.buffer;
        for t in 0..self.buf_len {
            f(self.k_ring.row((self.head + t) % cap));
        }
    }

    fn v_axpy_all(&self, ks: Kernels, w: &[f32], out: &mut [f32]) {
        self.v_sparse.axpy_all_with(ks, w, out);
    }

    fn for_each_ring_v(&self, mut f: impl FnMut(&[f32])) {
        let cap = self.params.buffer;
        for t in 0..self.buf_len {
            f(self.v_ring.row((self.head + t) % cap));
        }
    }
}

/// SWAN as a [`CachePolicy`] over the paged cache — the pool-mode
/// counterpart of [`crate::kvcache::SwanCache`], result-identical to it
/// token for token.
pub struct PagedSwanCache {
    cache: PagedHybridCache,
    seen: usize,
}

impl PagedSwanCache {
    pub fn new(
        d_h: usize,
        params: SwanParams,
        block_tokens: usize,
        pool: Arc<BlockPool>,
    ) -> PagedSwanCache {
        PagedSwanCache { cache: PagedHybridCache::new(d_h, params, block_tokens, pool), seen: 0 }
    }

    pub fn set_k_active(&mut self, k_keys: usize, k_vals: usize) {
        self.cache.set_k_active(k_keys, k_vals);
    }

    pub fn inner(&self) -> &PagedHybridCache {
        &self.cache
    }
}

impl CachePolicy for PagedSwanCache {
    fn append(&mut self, k_hat: &[f32], v_hat: &[f32]) {
        self.cache.append(k_hat, v_hat);
        self.seen += 1;
    }

    fn attend(&mut self, q_hat: &[f32], k_cur: &[f32], v_cur: &[f32], out: &mut [f32]) {
        let mut scores = Vec::with_capacity(self.cache.len() + 1);
        self.cache.attend(q_hat, k_cur, v_cur, &mut scores, out);
    }

    fn attend_with(
        &mut self,
        q_hat: &[f32],
        k_cur: &[f32],
        v_cur: &[f32],
        scratch: &mut AttentionScratch,
        out: &mut [f32],
    ) {
        self.cache.attend(q_hat, k_cur, v_cur, &mut scratch.scores, out);
    }

    fn load_history(&mut self, k_flat: &[f32], v_flat: &[f32], d: usize, _mass: Option<&[f32]>) {
        if d == 0 {
            return;
        }
        self.cache.load_prefill(k_flat, v_flat);
        self.seen += k_flat.len() / d;
    }

    fn storage_bytes(&self) -> usize {
        self.cache.storage_bytes()
    }

    fn retained_tokens(&self) -> usize {
        self.cache.len()
    }

    fn seen_tokens(&self) -> usize {
        self.seen
    }

    fn label(&self) -> String {
        format!(
            "swan-paged-{} k={}/{} bt={} blk={}",
            self.cache.params.mode.label(),
            self.cache.params.k_active_keys,
            self.cache.params.k_active_vals,
            self.cache.params.buffer,
            self.cache.k_sparse.geo.block_tokens
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::swan::HybridCache;
    use crate::util::Pcg64;

    fn pool() -> Arc<BlockPool> {
        Arc::new(BlockPool::new(usize::MAX))
    }

    /// Paged and contiguous caches stay bit-identical through appends —
    /// counts, Eq. 1 bytes, and attention outputs — including a runtime
    /// k change partway through.
    #[test]
    fn paged_matches_contiguous_through_appends() {
        let d = 32;
        let p = pool();
        let params = SwanParams::new(8, 3, crate::sparse::StorageMode::F16);
        let mut paged = PagedHybridCache::new(d, params, 4, p.clone());
        let mut flat = HybridCache::new(d, params);
        let mut r = Pcg64::new(9);
        for i in 0..25 {
            if i == 12 {
                paged.set_k_active(5, 3);
                flat.set_k_active(5, 3);
            }
            let k = r.normal_vec(d);
            let v = r.normal_vec(d);
            paged.append(&k, &v);
            flat.append(&k, &v);
            assert_eq!(paged.len(), flat.len());
            assert_eq!(paged.sparse_len(), flat.sparse_len());
            assert_eq!(paged.buffer_len(), flat.buffer_len());
            assert_eq!(paged.storage_bytes(), flat.storage_bytes(), "step {i}");

            let q = r.normal_vec(d);
            let kc = r.normal_vec(d);
            let vc = r.normal_vec(d);
            let mut a = vec![0.0; d];
            let mut b = vec![0.0; d];
            let mut s = Vec::new();
            paged.attend(&q, &kc, &vc, &mut s, &mut a);
            crate::swan::swan_attention(&q, &flat, &kc, &vc, &mut b);
            assert_eq!(a, b, "attention diverged at step {i}");
        }
        // sparse rows match entry-for-entry
        for rix in 0..paged.sparse_len() {
            let (vals, idx) = paged.k_sparse.row(rix);
            assert_eq!(vals, flat.k_sparse.row(rix).0, "row {rix}");
            assert_eq!(idx, flat.k_sparse.row(rix).1, "row {rix}");
            assert_eq!(paged.k_sparse.nnz(rix), flat.k_sparse.nnz(rix));
        }
        drop(paged);
        assert_eq!(p.leased(), 0, "drop must give every block back");
        p.check_invariants().unwrap();
    }

    /// Bulk prefill load matches the contiguous bulk path (which itself
    /// matches per-token appends).
    #[test]
    fn paged_load_prefill_matches_contiguous() {
        let d = 16;
        let p = pool();
        let params = SwanParams::new(6, 4, crate::sparse::StorageMode::F8);
        let mut paged = PagedHybridCache::new(d, params, 3, p.clone());
        let mut flat = HybridCache::new(d, params);
        let mut r = Pcg64::new(10);
        // non-empty start, then a bulk load that spills both old and new
        for _ in 0..2 {
            let k = r.normal_vec(d);
            let v = r.normal_vec(d);
            paged.append(&k, &v);
            flat.append(&k, &v);
        }
        let n = 11;
        let ks = r.normal_vec(n * d);
        let vs = r.normal_vec(n * d);
        paged.load_prefill(&ks, &vs);
        flat.load_prefill(&ks, &vs);
        assert_eq!(paged.len(), flat.len());
        assert_eq!(paged.storage_bytes(), flat.storage_bytes());
        let q = r.normal_vec(d);
        let kc = r.normal_vec(d);
        let vc = r.normal_vec(d);
        let mut a = vec![0.0; d];
        let mut b = vec![0.0; d];
        let mut s = Vec::new();
        paged.attend(&q, &kc, &vc, &mut s, &mut a);
        crate::swan::swan_attention(&q, &flat, &kc, &vc, &mut b);
        assert_eq!(a, b);
    }

    /// Block math: ring blocks lease up front, sparse blocks lease one
    /// per `block_tokens` evictions, and the analytic `seq_blocks` rate
    /// predicts the lease count exactly.
    #[test]
    fn lease_counts_follow_seq_blocks() {
        let d = 8;
        let bt = 2;
        let buffer = 3;
        let p = pool();
        let params = SwanParams::new(4, buffer, crate::sparse::StorageMode::F16);
        let mut c = PagedHybridCache::new(d, params, bt, p.clone());
        // ring: ceil(3/2) = 2 blocks per stream, 2 ring streams
        assert_eq!(p.leased(), 2 * 2);
        let mut r = Pcg64::new(11);
        for t in 1..=9 {
            c.append(&r.normal_vec(d), &r.normal_vec(d));
            // one (layer, head) pair = 1 "layer" x 1 "kv head" stream set
            assert_eq!(
                c.leased_blocks(),
                super::super::seq_blocks(t, buffer, bt, 1, 1) / 2,
                "token {t}"
            );
            assert_eq!(p.leased(), c.leased_blocks());
        }
        drop(c);
        assert_eq!(p.leased(), 0);
    }

    /// The policy adapter is result-identical to the contiguous SwanCache.
    #[test]
    fn paged_policy_matches_swan_cache() {
        let d = 16;
        let p = pool();
        let params = SwanParams::new(5, 2, crate::sparse::StorageMode::F16);
        let mut paged = PagedSwanCache::new(d, params, 4, p);
        let mut flat = crate::kvcache::SwanCache::new(d, params);
        let mut r = Pcg64::new(12);
        for _ in 0..20 {
            let k = r.normal_vec(d);
            let v = r.normal_vec(d);
            paged.append(&k, &v);
            flat.append(&k, &v);
        }
        assert_eq!(paged.seen_tokens(), flat.seen_tokens());
        assert_eq!(paged.retained_tokens(), flat.retained_tokens());
        assert_eq!(paged.storage_bytes(), flat.storage_bytes());
        let q = r.normal_vec(d);
        let kc = r.normal_vec(d);
        let vc = r.normal_vec(d);
        let mut a = vec![0.0; d];
        let mut b = vec![0.0; d];
        paged.attend(&q, &kc, &vc, &mut a);
        flat.attend(&q, &kc, &vc, &mut b);
        assert_eq!(a, b);
        assert!(paged.label().contains("paged"));
    }
}
