//! Algorithm 1 storage over pool blocks: paged sparse rows, paged dense
//! ring, and the [`PagedHybridCache`] / [`PagedSwanCache`] drop-ins.
//!
//! Bit-identity contract: every row lands in the same order, through the
//! same winnow ([`crate::sparse::winnow_into`]) and the same kernels, as
//! the contiguous [`HybridCache`](crate::swan::HybridCache) path.  The
//! per-block score walk folds per-block running maxima with `max` (exact
//! and order-insensitive), and the per-block scatter-add visits rows in
//! the same global order — so attention outputs match the contiguous
//! layout to the bit (`tests/pool.rs`).

// lint: allow(indexing, "block/slot arithmetic (r / block_tokens, r % block_tokens) over this cache's own row count cannot leave the table; the CSR walk is the decode hot path, where a bounds-checked accessor chain would cost real latency, and tests/pool.rs locks bit-identity against the contiguous path")

use std::sync::Arc;

use crate::kvcache::CachePolicy;
use crate::prefix::{EntryStream, TailRows};
use crate::simd::Kernels;
use crate::sparse::{winnow_into, StorageMode};
use crate::swan::attention::{swan_attend, SwanAttendable};
use crate::swan::batch::AttentionScratch;
use crate::swan::hybrid_cache::SwanParams;

use super::{BlockBuf, BlockGeometry, BlockPool, BlockTable};

/// One sparse stream (the paged analogue of
/// [`crate::sparse::SparseStore`]): winnowed CSR rows packed
/// `block_tokens` to a block, appended through the shared
/// [`winnow_into`] so quantization and lane padding are identical to the
/// contiguous store.  `bytes` accounting charges per-row *real* nnz
/// (Eq. 1), accumulated block by block.
pub struct PagedRows {
    table: BlockTable,
    geo: BlockGeometry,
    rows: usize,
}

impl PagedRows {
    pub fn new(pool: Arc<BlockPool>, geo: BlockGeometry) -> PagedRows {
        PagedRows { table: BlockTable::new(pool), geo, rows: 0 }
    }

    /// Winnow one dense row into the tail block (leasing a fresh block at
    /// every `block_tokens` boundary).
    pub fn push_pruned(&mut self, dense: &[f32], k: usize, mode: StorageMode) {
        let bt = self.geo.block_tokens;
        if self.rows % bt == 0 {
            let cap = self.geo.sparse_float_capacity();
            let b = self.table.push_block();
            b.vals.reserve(cap);
            b.idx.reserve(cap);
            b.offsets.reserve(bt);
            b.nnz.reserve(bt);
        }
        // lint: allow(panic, "the block-boundary branch above guarantees a tail block exists by the time any row is appended")
        let b = self.table.last_mut().unwrap();
        let nnz = winnow_into(dense, k, mode, self.geo.lanes, &mut b.vals, &mut b.idx);
        b.offsets.push(b.vals.len() as u32);
        b.nnz.push(nnz as u32);
        b.bytes += mode.vector_bytes(nnz);
        self.rows += 1;
    }

    /// Rows stored across all blocks.
    pub fn len(&self) -> usize {
        self.rows
    }

    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Real (unpadded) nnz of row `r`.
    pub fn nnz(&self, r: usize) -> usize {
        let bt = self.geo.block_tokens;
        self.table.buf(r / bt).nnz[r % bt] as usize
    }

    /// Live `(vals, idx)` entries of row `r` (padding excluded), for
    /// tests and reconstruction.
    pub fn row(&self, r: usize) -> (&[f32], &[u16]) {
        let bt = self.geo.block_tokens;
        let b = self.table.buf(r / bt);
        let local = r % bt;
        let start = b.offsets[local] as usize;
        let live = b.nnz[local] as usize;
        (&b.vals[start..start + live], &b.idx[start..start + live])
    }

    /// Accounted (Eq. 1) bytes — per-block real-nnz sums.
    pub fn storage_bytes(&self) -> usize {
        self.table.total_bytes()
    }

    /// The stream's block-table row (pool block ids in order), borrowed
    /// — the hot-path reader allocates nothing.
    pub fn block_ids(&self) -> &[u32] {
        self.table.block_ids()
    }

    /// Attach one full shared block (prefix reuse).  Only legal at a
    /// block boundary with a completely filled donor block; the block
    /// is read-only from here on (appends fork to a fresh owned tail).
    pub fn attach_shared(&mut self, b: &Arc<BlockBuf>) {
        debug_assert_eq!(self.rows % self.geo.block_tokens, 0);
        debug_assert_eq!(b.rows(), self.geo.block_tokens);
        self.rows += b.rows();
        self.table.push_shared(b.clone());
    }

    /// Copy a partial prefix tail into a freshly leased owned block —
    /// the mandatory tail fork: the donor's tail keeps growing under
    /// its own sequence, so the entry holds an immutable row copy and
    /// every attacher re-materializes it as private storage it can
    /// append into.  Bit-exact: the copied CSR rows are identical to
    /// what a cold run would have written.
    pub fn attach_tail(&mut self, tail: &TailRows) {
        debug_assert_eq!(self.rows % self.geo.block_tokens, 0);
        let cap = self.geo.sparse_float_capacity();
        let b = self.table.push_block();
        b.vals.reserve(cap);
        b.idx.reserve(cap);
        b.vals.extend_from_slice(&tail.vals);
        b.idx.extend_from_slice(&tail.idx);
        b.offsets.clear();
        b.offsets.extend_from_slice(&tail.offsets);
        b.nnz.extend_from_slice(&tail.nnz);
        b.bytes = tail.bytes;
        self.rows += tail.row_count();
    }

    /// Extract the first `rows` rows of this stream for a prefix-store
    /// entry: full blocks convert to refcounted shared form in place
    /// (zero copy — the sequence keeps reading them as before), the
    /// partial tail block's written rows copy out as [`TailRows`].
    /// Called at retire only; sparse rows are immutable once written,
    /// so the extracted prefix is exact regardless of how far past
    /// `rows` the stream has grown since.
    pub fn share_prefix(
        &mut self,
        rows: usize,
        mode: StorageMode,
    ) -> (Vec<Arc<BlockBuf>>, Option<TailRows>) {
        debug_assert!(rows <= self.rows);
        let bt = self.geo.block_tokens;
        let full = rows / bt;
        let mut shared = Vec::with_capacity(full);
        for i in 0..full {
            shared.push(self.table.share_block(i));
        }
        let rem = rows % bt;
        let tail = if rem == 0 {
            None
        } else {
            let b = self.table.buf(full);
            let end = b.offsets[rem] as usize;
            Some(TailRows {
                vals: b.vals[..end].to_vec(),
                idx: b.idx[..end].to_vec(),
                offsets: b.offsets[..=rem].to_vec(),
                nnz: b.nnz[..rem].to_vec(),
                bytes: b.nnz[..rem].iter().map(|&n| mode.vector_bytes(n as usize)).sum(),
            })
        };
        (shared, tail)
    }

    /// Blocks currently leased by this stream.
    pub fn block_count(&self) -> usize {
        self.table.len()
    }

    /// Fused CSR scores + running max across all blocks, oldest row
    /// first; one score pushed per row.  Per-block maxima fold with
    /// `max`, which equals the contiguous store's single-pass max.
    pub fn scores_max_into_with(
        &self,
        ks: Kernels,
        q: &[f32],
        scale: f32,
        out: &mut Vec<f32>,
    ) -> f32 {
        let mut m = f32::NEG_INFINITY;
        for s in self.table.slots() {
            let b = s.buf();
            let mb = ks.csr_scores_max_into(&b.vals, &b.idx, &b.offsets, scale, q, out);
            m = m.max(mb);
        }
        m
    }

    /// Weighted scatter-add of every row (`out += Σ w[r] * row_r`),
    /// slicing `w` block by block in global row order.
    pub fn axpy_all_with(&self, ks: Kernels, w: &[f32], out: &mut [f32]) {
        debug_assert_eq!(w.len(), self.rows);
        let mut r = 0;
        for s in self.table.slots() {
            let b = s.buf();
            let n = b.rows();
            ks.csr_axpy_all(&b.vals, &b.idx, &b.offsets, &w[r..r + n], out);
            r += n;
        }
    }
}

/// The dense recency ring's slot array, paged: `ceil(cap / block_tokens)`
/// blocks leased up front, each holding `block_tokens` rows of `d_head`
/// floats in `vals`.  Pure storage — FIFO state (`head`, `buf_len`) lives
/// on [`PagedHybridCache`], shared by the key and value rings exactly as
/// in the contiguous cache.
pub struct PagedRing {
    table: BlockTable,
    geo: BlockGeometry,
}

impl PagedRing {
    pub fn new(pool: Arc<BlockPool>, geo: BlockGeometry, cap: usize) -> PagedRing {
        let mut table = BlockTable::new(pool);
        let floats = geo.dense_floats();
        for _ in 0..cap.div_ceil(geo.block_tokens) {
            let b = table.push_block();
            b.vals.resize(floats, 0.0);
        }
        PagedRing { table, geo }
    }

    pub fn row(&self, slot: usize) -> &[f32] {
        let bt = self.geo.block_tokens;
        let d = self.geo.d_head;
        let off = (slot % bt) * d;
        &self.table.buf(slot / bt).vals[off..off + d]
    }

    pub fn row_mut(&mut self, slot: usize) -> &mut [f32] {
        let bt = self.geo.block_tokens;
        let d = self.geo.d_head;
        let off = (slot % bt) * d;
        &mut self.table.get_mut(slot / bt).vals[off..off + d]
    }

    pub fn block_count(&self) -> usize {
        self.table.len()
    }
}

/// The hybrid cache of Algorithm 1 over pool blocks — same FIFO
/// semantics, same winnow, same accounting as
/// [`crate::swan::HybridCache`], but every byte lives in fixed-size
/// leased blocks so sequences can be preempted and admitted at block
/// granularity.  One instance serves one (layer, kv-head) pair of one
/// sequence; all four streams (k/v × sparse/ring) lease from the same
/// pool.
pub struct PagedHybridCache {
    pub params: SwanParams,
    d_h: usize,
    pub k_sparse: PagedRows,
    pub v_sparse: PagedRows,
    k_ring: PagedRing,
    v_ring: PagedRing,
    /// Ring slot of the oldest live row (0 when empty).
    head: usize,
    buf_len: usize,
}

impl PagedHybridCache {
    pub fn new(
        d_h: usize,
        params: SwanParams,
        block_tokens: usize,
        pool: Arc<BlockPool>,
    ) -> PagedHybridCache {
        let mut params = params;
        params.lanes = params.resolved_lanes();
        let geo = BlockGeometry::new(block_tokens, d_h, params.lanes);
        PagedHybridCache {
            params,
            d_h,
            k_sparse: PagedRows::new(pool.clone(), geo),
            v_sparse: PagedRows::new(pool.clone(), geo),
            k_ring: PagedRing::new(pool.clone(), geo, params.buffer),
            v_ring: PagedRing::new(pool, geo, params.buffer),
            head: 0,
            buf_len: 0,
        }
    }

    pub fn d_h(&self) -> usize {
        self.d_h
    }

    pub fn buffer_len(&self) -> usize {
        self.buf_len
    }

    pub fn sparse_len(&self) -> usize {
        self.k_sparse.len()
    }

    pub fn len(&self) -> usize {
        self.buf_len + self.k_sparse.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocks this cache currently leases (all four streams).
    pub fn leased_blocks(&self) -> usize {
        self.k_sparse.block_count()
            + self.v_sparse.block_count()
            + self.k_ring.block_count()
            + self.v_ring.block_count()
    }

    pub fn set_k_active(&mut self, k_keys: usize, k_vals: usize) {
        self.params.k_active_keys = k_keys.min(self.d_h);
        self.params.k_active_vals = k_vals.min(self.d_h);
    }

    /// Mirror of [`crate::swan::HybridCache::append`]: fill the ring,
    /// winnow the oldest row out on overflow.
    pub fn append(&mut self, k_hat: &[f32], v_hat: &[f32]) {
        debug_assert_eq!(k_hat.len(), self.d_h);
        debug_assert_eq!(v_hat.len(), self.d_h);
        let cap = self.params.buffer;
        if cap == 0 {
            self.k_sparse.push_pruned(k_hat, self.params.k_active_keys, self.params.mode);
            self.v_sparse.push_pruned(v_hat, self.params.k_active_vals, self.params.mode);
            return;
        }
        if self.buf_len == cap {
            self.evict_oldest();
        }
        let slot = (self.head + self.buf_len) % cap;
        self.k_ring.row_mut(slot).copy_from_slice(k_hat);
        self.v_ring.row_mut(slot).copy_from_slice(v_hat);
        self.buf_len += 1;
    }

    fn evict_oldest(&mut self) {
        debug_assert!(self.buf_len > 0);
        self.k_sparse.push_pruned(
            self.k_ring.row(self.head),
            self.params.k_active_keys,
            self.params.mode,
        );
        self.v_sparse.push_pruned(
            self.v_ring.row(self.head),
            self.params.k_active_vals,
            self.params.mode,
        );
        self.head = (self.head + 1) % self.params.buffer;
        self.buf_len -= 1;
    }

    /// Mirror of [`crate::swan::HybridCache::load_prefill`]: spill
    /// existing ring rows FIFO, winnow the incoming head straight to
    /// sparse, copy the tail into ring slots.
    pub fn load_prefill(&mut self, k_hats: &[f32], v_hats: &[f32]) {
        let d = self.d_h;
        let n = k_hats.len() / d;
        debug_assert_eq!(k_hats.len(), n * d);
        debug_assert_eq!(v_hats.len(), n * d);
        let cap = self.params.buffer;
        let spill = (self.buf_len + n).saturating_sub(cap);
        let spill_old = spill.min(self.buf_len);
        for _ in 0..spill_old {
            self.evict_oldest();
        }
        let spill_new = spill - spill_old;
        for t in 0..spill_new {
            self.k_sparse.push_pruned(
                &k_hats[t * d..(t + 1) * d],
                self.params.k_active_keys,
                self.params.mode,
            );
            self.v_sparse.push_pruned(
                &v_hats[t * d..(t + 1) * d],
                self.params.k_active_vals,
                self.params.mode,
            );
        }
        for t in spill_new..n {
            let slot = (self.head + self.buf_len) % cap;
            self.k_ring.row_mut(slot).copy_from_slice(&k_hats[t * d..(t + 1) * d]);
            self.v_ring.row_mut(slot).copy_from_slice(&v_hats[t * d..(t + 1) * d]);
            self.buf_len += 1;
        }
    }

    /// Serving-accounting bytes: per-block real-nnz Eq. 1 sums for the
    /// sparse streams, the f16 convention for live ring rows — the same
    /// total the contiguous cache reports.
    pub fn storage_bytes(&self) -> usize {
        let sparse = self.k_sparse.storage_bytes() + self.v_sparse.storage_bytes();
        let dense = 2 * self.buf_len * self.d_h * 2; // k+v, f16
        sparse + dense
    }

    pub fn dense_equiv_bytes(&self) -> usize {
        2 * self.len() * self.d_h * 2
    }

    /// Plain copies of the live ring rows, oldest first — the
    /// order-normalized dense state a prefix-store entry keeps (ring
    /// storage is mutated in place as decode wraps, so entries copy it
    /// instead of sharing; it must be captured at the moment the cache
    /// holds exactly the prefix depth).
    pub fn ring_snapshot(&self) -> (Vec<f32>, Vec<f32>) {
        let cap = self.params.buffer;
        let mut k = Vec::with_capacity(self.buf_len * self.d_h);
        let mut v = Vec::with_capacity(self.buf_len * self.d_h);
        for t in 0..self.buf_len {
            let slot = (self.head + t) % cap;
            k.extend_from_slice(self.k_ring.row(slot));
            v.extend_from_slice(self.v_ring.row(slot));
        }
        (k, v)
    }

    /// Seed an empty cache from a prefix-store stream: full sparse
    /// blocks attach copy-on-write (refcount-pinned, read-only), the
    /// partial sparse tail and the ring rows copy into freshly leased
    /// owned storage.  The result is bit-identical to a cold cache that
    /// appended the same `depth` tokens — the reuse contract: winnowed
    /// state is a pure function of tokens x compression config.  (The
    /// attached ring lands at `head == 0` in oldest-first order; the
    /// physical slot phase differs from the donor's, but every reader
    /// and writer goes through the same logical FIFO indexing, so the
    /// states are observationally — hence bitwise — equivalent.)
    pub fn attach_prefix(&mut self, s: &EntryStream, depth: usize) {
        debug_assert!(self.is_empty());
        for b in &s.full_k {
            self.k_sparse.attach_shared(b);
        }
        if let Some(t) = &s.tail_k {
            self.k_sparse.attach_tail(t);
        }
        for b in &s.full_v {
            self.v_sparse.attach_shared(b);
        }
        if let Some(t) = &s.tail_v {
            self.v_sparse.attach_tail(t);
        }
        let d = self.d_h;
        let ring_rows = if d == 0 { 0 } else { s.ring_k.len() / d };
        for t in 0..ring_rows {
            self.k_ring.row_mut(t).copy_from_slice(&s.ring_k[t * d..(t + 1) * d]);
            self.v_ring.row_mut(t).copy_from_slice(&s.ring_v[t * d..(t + 1) * d]);
        }
        self.head = 0;
        self.buf_len = ring_rows;
        debug_assert_eq!(self.len(), depth);
    }

    /// Extract the first `depth` tokens as a prefix-store entry.  The
    /// caller supplies the ring snapshot captured when the cache held
    /// exactly `depth` tokens (later winnowing destroys that state)
    /// plus the pool the entry pins its shared blocks against.
    pub fn share_prefix(
        &mut self,
        depth: usize,
        rings: (Vec<f32>, Vec<f32>),
        pool: Arc<BlockPool>,
    ) -> EntryStream {
        let sparse_rows = depth.saturating_sub(self.params.buffer);
        let mode = self.params.mode;
        let (full_k, tail_k) = self.k_sparse.share_prefix(sparse_rows, mode);
        let (full_v, tail_v) = self.v_sparse.share_prefix(sparse_rows, mode);
        EntryStream { pool, full_k, full_v, tail_k, tail_v, ring_k: rings.0, ring_v: rings.1 }
    }

    /// Read-only attention via the shared generic walk.
    pub fn attend(
        &self,
        q_hat: &[f32],
        k_hat_cur: &[f32],
        v_hat_cur: &[f32],
        scores: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        swan_attend(q_hat, self, k_hat_cur, v_hat_cur, scores, out);
    }
}

impl SwanAttendable for PagedHybridCache {
    fn d_h(&self) -> usize {
        PagedHybridCache::d_h(self)
    }

    fn sparse_len(&self) -> usize {
        PagedHybridCache::sparse_len(self)
    }

    fn buffer_len(&self) -> usize {
        PagedHybridCache::buffer_len(self)
    }

    fn k_scores_max_into(&self, ks: Kernels, q: &[f32], scale: f32, out: &mut Vec<f32>) -> f32 {
        self.k_sparse.scores_max_into_with(ks, q, scale, out)
    }

    fn for_each_ring_k(&self, mut f: impl FnMut(&[f32])) {
        let cap = self.params.buffer;
        for t in 0..self.buf_len {
            f(self.k_ring.row((self.head + t) % cap));
        }
    }

    fn v_axpy_all(&self, ks: Kernels, w: &[f32], out: &mut [f32]) {
        self.v_sparse.axpy_all_with(ks, w, out);
    }

    fn for_each_ring_v(&self, mut f: impl FnMut(&[f32])) {
        let cap = self.params.buffer;
        for t in 0..self.buf_len {
            f(self.v_ring.row((self.head + t) % cap));
        }
    }
}

/// SWAN as a [`CachePolicy`] over the paged cache — the pool-mode
/// counterpart of [`crate::kvcache::SwanCache`], result-identical to it
/// token for token.
pub struct PagedSwanCache {
    cache: PagedHybridCache,
    seen: usize,
}

impl PagedSwanCache {
    pub fn new(
        d_h: usize,
        params: SwanParams,
        block_tokens: usize,
        pool: Arc<BlockPool>,
    ) -> PagedSwanCache {
        PagedSwanCache { cache: PagedHybridCache::new(d_h, params, block_tokens, pool), seen: 0 }
    }

    pub fn set_k_active(&mut self, k_keys: usize, k_vals: usize) {
        self.cache.set_k_active(k_keys, k_vals);
    }

    pub fn inner(&self) -> &PagedHybridCache {
        &self.cache
    }

    /// See [`PagedHybridCache::ring_snapshot`].
    pub fn ring_snapshot(&self) -> (Vec<f32>, Vec<f32>) {
        self.cache.ring_snapshot()
    }

    /// See [`PagedHybridCache::attach_prefix`]; also fast-forwards the
    /// seen-token count to the attached depth.
    pub fn attach_prefix(&mut self, s: &EntryStream, depth: usize) {
        self.cache.attach_prefix(s, depth);
        self.seen = depth;
    }

    /// See [`PagedHybridCache::share_prefix`].
    pub fn share_prefix(
        &mut self,
        depth: usize,
        rings: (Vec<f32>, Vec<f32>),
        pool: Arc<BlockPool>,
    ) -> EntryStream {
        self.cache.share_prefix(depth, rings, pool)
    }
}

impl CachePolicy for PagedSwanCache {
    fn append(&mut self, k_hat: &[f32], v_hat: &[f32]) {
        self.cache.append(k_hat, v_hat);
        self.seen += 1;
    }

    fn attend(&mut self, q_hat: &[f32], k_cur: &[f32], v_cur: &[f32], out: &mut [f32]) {
        let mut scores = Vec::with_capacity(self.cache.len() + 1);
        self.cache.attend(q_hat, k_cur, v_cur, &mut scores, out);
    }

    fn attend_with(
        &mut self,
        q_hat: &[f32],
        k_cur: &[f32],
        v_cur: &[f32],
        scratch: &mut AttentionScratch,
        out: &mut [f32],
    ) {
        self.cache.attend(q_hat, k_cur, v_cur, &mut scratch.scores, out);
    }

    fn load_history(&mut self, k_flat: &[f32], v_flat: &[f32], d: usize, _mass: Option<&[f32]>) {
        if d == 0 {
            return;
        }
        self.cache.load_prefill(k_flat, v_flat);
        self.seen += k_flat.len() / d;
    }

    fn storage_bytes(&self) -> usize {
        self.cache.storage_bytes()
    }

    fn retained_tokens(&self) -> usize {
        self.cache.len()
    }

    fn seen_tokens(&self) -> usize {
        self.seen
    }

    fn as_paged(&mut self) -> Option<&mut PagedSwanCache> {
        Some(self)
    }

    fn label(&self) -> String {
        format!(
            "swan-paged-{} k={}/{} bt={} blk={}",
            self.cache.params.mode.label(),
            self.cache.params.k_active_keys,
            self.cache.params.k_active_vals,
            self.cache.params.buffer,
            self.cache.k_sparse.geo.block_tokens
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::swan::HybridCache;
    use crate::util::Pcg64;

    fn pool() -> Arc<BlockPool> {
        Arc::new(BlockPool::new(usize::MAX))
    }

    /// Paged and contiguous caches stay bit-identical through appends —
    /// counts, Eq. 1 bytes, and attention outputs — including a runtime
    /// k change partway through.
    #[test]
    fn paged_matches_contiguous_through_appends() {
        let d = 32;
        let p = pool();
        let params = SwanParams::new(8, 3, crate::sparse::StorageMode::F16);
        let mut paged = PagedHybridCache::new(d, params, 4, p.clone());
        let mut flat = HybridCache::new(d, params);
        let mut r = Pcg64::new(9);
        for i in 0..25 {
            if i == 12 {
                paged.set_k_active(5, 3);
                flat.set_k_active(5, 3);
            }
            let k = r.normal_vec(d);
            let v = r.normal_vec(d);
            paged.append(&k, &v);
            flat.append(&k, &v);
            assert_eq!(paged.len(), flat.len());
            assert_eq!(paged.sparse_len(), flat.sparse_len());
            assert_eq!(paged.buffer_len(), flat.buffer_len());
            assert_eq!(paged.storage_bytes(), flat.storage_bytes(), "step {i}");

            let q = r.normal_vec(d);
            let kc = r.normal_vec(d);
            let vc = r.normal_vec(d);
            let mut a = vec![0.0; d];
            let mut b = vec![0.0; d];
            let mut s = Vec::new();
            paged.attend(&q, &kc, &vc, &mut s, &mut a);
            crate::swan::swan_attention(&q, &flat, &kc, &vc, &mut b);
            assert_eq!(a, b, "attention diverged at step {i}");
        }
        // sparse rows match entry-for-entry
        for rix in 0..paged.sparse_len() {
            let (vals, idx) = paged.k_sparse.row(rix);
            assert_eq!(vals, flat.k_sparse.row(rix).0, "row {rix}");
            assert_eq!(idx, flat.k_sparse.row(rix).1, "row {rix}");
            assert_eq!(paged.k_sparse.nnz(rix), flat.k_sparse.nnz(rix));
        }
        drop(paged);
        assert_eq!(p.leased(), 0, "drop must give every block back");
        p.check_invariants().unwrap();
    }

    /// Bulk prefill load matches the contiguous bulk path (which itself
    /// matches per-token appends).
    #[test]
    fn paged_load_prefill_matches_contiguous() {
        let d = 16;
        let p = pool();
        let params = SwanParams::new(6, 4, crate::sparse::StorageMode::F8);
        let mut paged = PagedHybridCache::new(d, params, 3, p.clone());
        let mut flat = HybridCache::new(d, params);
        let mut r = Pcg64::new(10);
        // non-empty start, then a bulk load that spills both old and new
        for _ in 0..2 {
            let k = r.normal_vec(d);
            let v = r.normal_vec(d);
            paged.append(&k, &v);
            flat.append(&k, &v);
        }
        let n = 11;
        let ks = r.normal_vec(n * d);
        let vs = r.normal_vec(n * d);
        paged.load_prefill(&ks, &vs);
        flat.load_prefill(&ks, &vs);
        assert_eq!(paged.len(), flat.len());
        assert_eq!(paged.storage_bytes(), flat.storage_bytes());
        let q = r.normal_vec(d);
        let kc = r.normal_vec(d);
        let vc = r.normal_vec(d);
        let mut a = vec![0.0; d];
        let mut b = vec![0.0; d];
        let mut s = Vec::new();
        paged.attend(&q, &kc, &vc, &mut s, &mut a);
        crate::swan::swan_attention(&q, &flat, &kc, &vc, &mut b);
        assert_eq!(a, b);
    }

    /// Block math: ring blocks lease up front, sparse blocks lease one
    /// per `block_tokens` evictions, and the analytic `seq_blocks` rate
    /// predicts the lease count exactly.
    #[test]
    fn lease_counts_follow_seq_blocks() {
        let d = 8;
        let bt = 2;
        let buffer = 3;
        let p = pool();
        let params = SwanParams::new(4, buffer, crate::sparse::StorageMode::F16);
        let mut c = PagedHybridCache::new(d, params, bt, p.clone());
        // ring: ceil(3/2) = 2 blocks per stream, 2 ring streams
        assert_eq!(p.leased(), 2 * 2);
        let mut r = Pcg64::new(11);
        for t in 1..=9 {
            c.append(&r.normal_vec(d), &r.normal_vec(d));
            // one (layer, head) pair = 1 "layer" x 1 "kv head" stream set
            assert_eq!(
                c.leased_blocks(),
                super::super::seq_blocks(t, buffer, bt, 1, 1) / 2,
                "token {t}"
            );
            assert_eq!(p.leased(), c.leased_blocks());
        }
        drop(c);
        assert_eq!(p.leased(), 0);
    }

    /// COW prefix round trip: an entry extracted at depth m re-attaches
    /// into an empty cache whose subsequent appends are bit-identical
    /// to a cold cache fed the same rows, the donor keeps decoding past
    /// the share unaffected (tail fork), and every block frees once the
    /// last holder lets go.
    #[test]
    fn prefix_attach_matches_cold_and_frees_blocks() {
        let d = 16;
        let p = pool();
        let params = SwanParams::new(5, 3, crate::sparse::StorageMode::F16);
        let bt = 4;
        let mut r = Pcg64::new(13);
        let rows: Vec<(Vec<f32>, Vec<f32>)> =
            (0..23).map(|_| (r.normal_vec(d), r.normal_vec(d))).collect();
        let depth = 17; // sparse 14 rows = 3 full blocks + 2 tail rows

        // donor: append depth rows, snapshot the ring, keep decoding
        let mut donor = PagedHybridCache::new(d, params, bt, p.clone());
        for (k, v) in &rows[..depth] {
            donor.append(k, v);
        }
        let rings = donor.ring_snapshot();
        for (k, v) in &rows[depth..] {
            donor.append(k, v);
        }
        let entry = donor.share_prefix(depth, rings, p.clone());

        // warm: attach the entry, then append the remaining rows
        let mut warm = PagedHybridCache::new(d, params, bt, p.clone());
        warm.attach_prefix(&entry, depth);
        assert_eq!(warm.len(), depth);
        for (k, v) in &rows[depth..] {
            warm.append(k, v);
        }

        // cold reference over the full row set
        let mut cold = PagedHybridCache::new(d, params, bt, p.clone());
        for (k, v) in &rows {
            cold.append(k, v);
        }

        assert_eq!(warm.len(), cold.len());
        assert_eq!(warm.storage_bytes(), cold.storage_bytes());
        for rix in 0..cold.sparse_len() {
            assert_eq!(warm.k_sparse.row(rix), cold.k_sparse.row(rix), "k row {rix}");
            assert_eq!(warm.v_sparse.row(rix), cold.v_sparse.row(rix), "v row {rix}");
            // ...and the donor's own early rows were never mutated
            assert_eq!(donor.k_sparse.row(rix), cold.k_sparse.row(rix), "donor k row {rix}");
        }
        let q = r.normal_vec(d);
        let kc = r.normal_vec(d);
        let vc = r.normal_vec(d);
        let mut a = vec![0.0; d];
        let mut b = vec![0.0; d];
        let mut s1 = Vec::new();
        let mut s2 = Vec::new();
        warm.attend(&q, &kc, &vc, &mut s1, &mut a);
        cold.attend(&q, &kc, &vc, &mut s2, &mut b);
        assert_eq!(a, b, "warm attention must match cold bit for bit");

        drop(donor);
        drop(warm);
        drop(cold);
        assert!(p.leased() > 0, "entry still pins its shared blocks");
        drop(entry);
        assert_eq!(p.leased(), 0, "releasing the entry frees the last references");
        p.check_invariants().unwrap();
    }

    /// The policy adapter is result-identical to the contiguous SwanCache.
    #[test]
    fn paged_policy_matches_swan_cache() {
        let d = 16;
        let p = pool();
        let params = SwanParams::new(5, 2, crate::sparse::StorageMode::F16);
        let mut paged = PagedSwanCache::new(d, params, 4, p);
        let mut flat = crate::kvcache::SwanCache::new(d, params);
        let mut r = Pcg64::new(12);
        for _ in 0..20 {
            let k = r.normal_vec(d);
            let v = r.normal_vec(d);
            paged.append(&k, &v);
            flat.append(&k, &v);
        }
        assert_eq!(paged.seen_tokens(), flat.seen_tokens());
        assert_eq!(paged.retained_tokens(), flat.retained_tokens());
        assert_eq!(paged.storage_bytes(), flat.storage_bytes());
        let q = r.normal_vec(d);
        let kc = r.normal_vec(d);
        let vc = r.normal_vec(d);
        let mut a = vec![0.0; d];
        let mut b = vec![0.0; d];
        paged.attend(&q, &kc, &vc, &mut a);
        flat.attend(&q, &kc, &vc, &mut b);
        assert_eq!(a, b);
        assert!(paged.label().contains("paged"));
    }
}
