//! Free-list block allocator: block ids, refcounts, double-free checks.
//!
//! The allocator manages *identities*, not storage — [`super::BlockPool`]
//! pairs each live id with an owned [`super::BlockBuf`].  Refcounts are
//! always 1 under today's serving paths; `retain` exists as the
//! copy-on-write hook prefix sharing will build on (see ROADMAP).

// lint: allow(indexing, "every index is an allocator-issued id into the self-owned refcounts vec, dense 0..capacity by construction; check_invariants locks the correspondence and tests/prop_invariants.rs exercises it")

/// Fixed-universe id allocator with a LIFO free list and per-id
/// refcounts.  Ids are dense `0..capacity`; [`BlockAllocator::grow_one`]
/// extends the universe when an elastic pool leases past its initial
/// sizing.
#[derive(Debug)]
pub struct BlockAllocator {
    /// `refcounts[id] == 0` exactly when `id` is on the free list.
    refcounts: Vec<u32>,
    /// Free ids, most-recently-freed on top (LIFO reuses warm buffers).
    free: Vec<u32>,
}

impl BlockAllocator {
    /// An allocator over ids `0..n_blocks`, all free.  The free list is
    /// stacked so that id 0 is handed out first.
    pub fn new(n_blocks: usize) -> BlockAllocator {
        BlockAllocator {
            refcounts: vec![0; n_blocks],
            free: (0..n_blocks as u32).rev().collect(),
        }
    }

    /// Total ids in the universe (free + live).
    pub fn capacity(&self) -> usize {
        self.refcounts.len()
    }

    /// Ids currently leased (refcount >= 1).
    pub fn live(&self) -> usize {
        self.refcounts.len() - self.free.len()
    }

    /// Pop a free id at refcount 1, or `None` when the universe is
    /// exhausted.
    pub fn alloc(&mut self) -> Option<u32> {
        let id = self.free.pop()?;
        debug_assert_eq!(self.refcounts[id as usize], 0, "free-list id {id} had a refcount");
        self.refcounts[id as usize] = 1;
        Some(id)
    }

    /// [`BlockAllocator::alloc`], extending the universe by one id when
    /// the free list is empty (elastic pools never fail a lease; the
    /// budget is enforced analytically by the serving coordinator).
    pub fn alloc_grow(&mut self) -> u32 {
        if let Some(id) = self.alloc() {
            return id;
        }
        let id = self.refcounts.len() as u32;
        self.refcounts.push(1);
        id
    }

    /// Current refcount of `id`.
    pub fn refcount(&self, id: u32) -> u32 {
        self.refcounts[id as usize]
    }

    /// Add one reference (the copy-on-write sharing hook).  Panics on a
    /// free id — sharing a block nobody holds is always a caller bug.
    pub fn retain(&mut self, id: u32) {
        let rc = &mut self.refcounts[id as usize];
        assert!(*rc > 0, "retain of free block {id}");
        *rc += 1;
    }

    /// Drop one reference; returns `true` when the block became free and
    /// went back on the free list.  Panics on a free id (double-free).
    pub fn release(&mut self, id: u32) -> bool {
        let rc = &mut self.refcounts[id as usize];
        assert!(*rc > 0, "double-free of block {id}");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(id);
            true
        } else {
            false
        }
    }

    /// Structural invariants, first violation as an error: ids on the
    /// free list are in range, unique, and at refcount 0; every
    /// refcount-0 id is on the free list (conservation — no id is ever
    /// lost or duplicated).
    pub fn check_invariants(&self) -> Result<(), String> {
        let cap = self.refcounts.len();
        let mut on_free = vec![false; cap];
        for &id in &self.free {
            let i = id as usize;
            if i >= cap {
                return Err(format!("free id {id} out of range (capacity {cap})"));
            }
            if on_free[i] {
                return Err(format!("free list holds id {id} twice"));
            }
            on_free[i] = true;
            if self.refcounts[i] != 0 {
                return Err(format!("free id {id} has refcount {}", self.refcounts[i]));
            }
        }
        for (i, &rc) in self.refcounts.iter().enumerate() {
            if rc == 0 && !on_free[i] {
                return Err(format!("id {i} has refcount 0 but is not on the free list"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_conserves_ids() {
        let mut a = BlockAllocator::new(4);
        assert_eq!(a.capacity(), 4);
        assert_eq!(a.live(), 0);
        let ids: Vec<u32> = (0..4).map(|_| a.alloc().unwrap()).collect();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert_eq!(a.live(), 4);
        assert!(a.alloc().is_none());
        assert!(a.release(2));
        assert_eq!(a.alloc(), Some(2)); // LIFO reuse
        a.check_invariants().unwrap();
    }

    #[test]
    fn refcounts_gate_freeing() {
        let mut a = BlockAllocator::new(1);
        let id = a.alloc().unwrap();
        a.retain(id);
        assert_eq!(a.refcount(id), 2);
        assert!(!a.release(id)); // still shared
        assert!(a.release(id)); // now free
        assert_eq!(a.live(), 0);
        a.check_invariants().unwrap();
    }

    #[test]
    fn grow_extends_universe() {
        let mut a = BlockAllocator::new(0);
        assert_eq!(a.alloc_grow(), 0);
        assert_eq!(a.alloc_grow(), 1);
        assert_eq!(a.capacity(), 2);
        assert_eq!(a.live(), 2);
        a.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "double-free")]
    fn double_free_panics() {
        let mut a = BlockAllocator::new(1);
        let id = a.alloc().unwrap();
        a.release(id);
        a.release(id);
    }

    #[test]
    #[should_panic(expected = "retain of free block")]
    fn retain_of_free_panics() {
        let mut a = BlockAllocator::new(1);
        a.retain(0);
    }
}
