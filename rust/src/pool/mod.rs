//! Paged KV block pool: fixed-size blocks, per-sequence block tables,
//! and the pool-backed hybrid cache.
//!
//! Per-sequence `HybridCache`s grow as they go; at serving scale that
//! fragments memory and makes preemption all-or-nothing.  This module
//! rebuilds SWAN storage as a block pool:
//!
//! * [`BlockPool`] — a process-level (per pipeline stage) recycler of
//!   owned [`BlockBuf`] storage with a free-list [`BlockAllocator`]
//!   tracking block ids and refcounts.  Leases hand blocks out **by
//!   value**, so the decode hot path touches no lock — the mutex is hit
//!   only when a sequence grows past a block boundary (every
//!   `block_tokens` tokens) or retires.
//! * [`BlockTable`] — one stream's leased blocks in row order (the
//!   per-sequence block table); [`BlockGeometry`] fixes the shared block
//!   shape, lane-multiple aware so the per-block CSR walks stay
//!   tail-free.
//! * [`PagedHybridCache`] / [`PagedSwanCache`] — Algorithm 1 over paged
//!   storage, bit-identical to the contiguous
//!   [`crate::swan::HybridCache`] (`tests/pool.rs` locks it down), with
//!   per-block real-nnz accounting so Eq. 1 bytes stay exact under
//!   mixed per-request k.
//!
//! # Elasticity and the budget
//!
//! A lease never fails: the pool grows past its target when asked (the
//! allocator extends its id universe).  Bounding is *analytic* — the
//! serving coordinator computes every sequence's block count in closed
//! form ([`seq_blocks`]) from its token count, admits only when the sum
//! fits the target, and preempts block-granularly when decode growth
//! overruns it.  That keeps admission race-free without any async
//! reservation protocol between coordinator and stage threads.
//!
//! Naming note: `coordinator::pool` is the unrelated byte-array lease
//! pool for PJRT execution buffers; this crate-root module is the KV
//! *block* pool.

pub mod allocator;
pub mod block_table;
pub mod paged_cache;

pub use allocator::BlockAllocator;
pub use block_table::{
    block_bytes, block_ceil_bytes, pool_blocks_for_budget, seq_blocks, BlockGeometry, BlockTable,
    Slot,
};
pub use paged_cache::{PagedHybridCache, PagedSwanCache};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::obs::histogram::Histogram;
use crate::obs::registry::Registry;
use crate::util::sync::lock_recover;

/// Optional pool-latency instruments: how long `lease` / `give_back`
/// spend inside the pool (lock wait + free-list work).  Recording is a
/// lock-free histogram append and happens AFTER the pool mutex drops,
/// so instrumented pools serialize exactly like bare ones.
#[derive(Clone)]
pub struct PoolObs {
    pub lease_seconds: Arc<Histogram>,
    pub give_back_seconds: Arc<Histogram>,
}

impl PoolObs {
    /// Register the pool instruments under a stage label (pipeline
    /// groups run one pool per stage).
    pub fn register(registry: &Registry, stage: usize) -> PoolObs {
        let s = stage.to_string();
        PoolObs {
            lease_seconds: registry.histogram("swan_pool_lease_seconds", &[("stage", &s)]),
            give_back_seconds: registry.histogram("swan_pool_give_back_seconds", &[("stage", &s)]),
        }
    }
}

/// One owned block of cache storage, leased from a [`BlockPool`].
///
/// Sparse streams use the full CSR-per-block layout: `block_tokens` (or
/// fewer, in the still-filling tail block) rows in `vals`/`idx`, padded
/// row boundaries in `offsets` (`rows + 1`, starting at 0), real nnz per
/// row in `nnz`, and `bytes` accumulating the Eq. 1 charge of the rows
/// actually written — per-block *real-nnz* accounting, so mixed
/// per-request k stays exact.  Dense-ring blocks use `vals` only
/// (`block_tokens * d_head` floats) and leave the CSR fields at their
/// reset state with `bytes == 0` (ring bytes are charged analytically by
/// the cache, matching `HybridCache::storage_bytes`).
#[derive(Debug)]
pub struct BlockBuf {
    /// Pool block id (the block-table entry value).
    pub id: u32,
    pub vals: Vec<f32>,
    pub idx: Vec<u16>,
    /// Padded row boundaries within this block; `offsets.len() == rows + 1`.
    pub offsets: Vec<u32>,
    /// Real (unpadded) nnz per row.
    pub nnz: Vec<u32>,
    /// Eq. 1 bytes of the rows written into this block.
    pub bytes: usize,
}

impl BlockBuf {
    fn fresh(id: u32) -> BlockBuf {
        // lint: allow(hot_alloc, "empty Vec::new() does not allocate; block setup is amortized over block_rows tokens")
        BlockBuf { id, vals: Vec::new(), idx: Vec::new(), offsets: vec![0], nnz: Vec::new(), bytes: 0 }
    }

    /// Rows currently written (sparse blocks; 0 for dense-ring blocks).
    pub fn rows(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Clear contents for reuse under a new lease, keeping allocations.
    fn reset(&mut self, id: u32) {
        self.id = id;
        self.vals.clear();
        self.idx.clear();
        self.offsets.clear();
        self.offsets.push(0);
        self.nnz.clear();
        self.bytes = 0;
    }
}

struct PoolInner {
    alloc: BlockAllocator,
    /// Returned buffers awaiting re-lease (allocations kept warm).
    spare: Vec<BlockBuf>,
}

/// Shared block pool for one serving scope (one pipeline stage, or one
/// test harness).  See the module docs for the lease-by-value /
/// analytic-budget design.
pub struct BlockPool {
    inner: Mutex<PoolInner>,
    /// Blocks the memory budget sized this pool for (`usize::MAX` =
    /// unbounded).  Advisory: leases are elastic; the coordinator
    /// enforces the target analytically.
    target_blocks: usize,
    /// Lock-free lease gauge for STATS rendering.
    leased: AtomicUsize,
    /// Latency instruments (None for bare pools).
    obs: Option<PoolObs>,
}

impl BlockPool {
    pub fn new(target_blocks: usize) -> BlockPool {
        BlockPool {
            inner: Mutex::new(PoolInner { alloc: BlockAllocator::new(0), spare: Vec::new() }),
            target_blocks,
            leased: AtomicUsize::new(0),
            obs: None,
        }
    }

    /// A pool whose lease/give-back latencies record into `obs`.
    pub fn with_obs(target_blocks: usize, obs: PoolObs) -> BlockPool {
        BlockPool { obs: Some(obs), ..BlockPool::new(target_blocks) }
    }

    /// Lease one block (never fails — see module docs).  The returned
    /// buffer is owned by the caller until [`BlockPool::give_back`].
    pub fn lease(&self) -> BlockBuf {
        let t0 = self.obs.as_ref().map(|_| Instant::now());
        let mut g = lock_recover(&self.inner);
        let id = g.alloc.alloc_grow();
        let buf = match g.spare.pop() {
            Some(mut b) => {
                b.reset(id);
                b
            }
            None => BlockBuf::fresh(id),
        };
        drop(g);
        self.leased.fetch_add(1, Ordering::Relaxed);
        if let (Some(obs), Some(t0)) = (&self.obs, t0) {
            obs.lease_seconds.record(t0.elapsed());
        }
        buf
    }

    /// Return a leased block.  When this was the last reference the id
    /// frees, the lease gauge falls, and the storage recycles; a block
    /// still shared with a prefix-store entry (see [`BlockPool::share`])
    /// merely drops one reference.
    pub fn give_back(&self, buf: BlockBuf) {
        let t0 = self.obs.as_ref().map(|_| Instant::now());
        let mut g = lock_recover(&self.inner);
        let freed = g.alloc.release(buf.id);
        if freed {
            g.spare.push(buf);
        }
        drop(g);
        if freed {
            self.leased.fetch_sub(1, Ordering::Relaxed);
        }
        if let (Some(obs), Some(t0)) = (&self.obs, t0) {
            obs.give_back_seconds.record(t0.elapsed());
        }
    }

    /// Add one reference to a live block — the copy-on-write hook used
    /// by prefix sharing.  The caller now holds `id` alongside its
    /// existing holder(s) and must balance with
    /// [`BlockPool::release_shared`] (or [`BlockPool::give_back`] for
    /// the original by-value lease).  The lease gauge counts *unique*
    /// live ids, so sharing does not move it; shared blocks are never
    /// mutated (appends always target an owned tail block).
    pub fn share(&self, id: u32) {
        let mut g = lock_recover(&self.inner);
        g.alloc.retain(id);
    }

    /// Drop one shared (`Arc`-held) reference.  When it was the last,
    /// the id frees, the gauge falls, and — since the refcount
    /// discipline ties one allocator reference to each `Arc` clone —
    /// the unwrap succeeds and the buffer recycles.
    pub fn release_shared(&self, arc: Arc<BlockBuf>) {
        let t0 = self.obs.as_ref().map(|_| Instant::now());
        let mut g = lock_recover(&self.inner);
        let freed = g.alloc.release(arc.id);
        if freed {
            if let Ok(buf) = Arc::try_unwrap(arc) {
                g.spare.push(buf);
            }
        }
        drop(g);
        if freed {
            self.leased.fetch_sub(1, Ordering::Relaxed);
        }
        if let (Some(obs), Some(t0)) = (&self.obs, t0) {
            obs.give_back_seconds.record(t0.elapsed());
        }
    }

    /// Blocks currently leased out.
    pub fn leased(&self) -> usize {
        self.leased.load(Ordering::Relaxed)
    }

    /// The budget-derived sizing target (`usize::MAX` = unbounded).
    pub fn target_blocks(&self) -> usize {
        self.target_blocks
    }

    /// Allocator invariants plus gauge consistency (tests).
    pub fn check_invariants(&self) -> Result<(), String> {
        let g = lock_recover(&self.inner);
        g.alloc.check_invariants()?;
        if g.alloc.live() != self.leased.load(Ordering::Relaxed) {
            return Err(format!(
                "lease gauge {} != allocator live {}",
                self.leased.load(Ordering::Relaxed),
                g.alloc.live()
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_recycles_buffers_and_tracks_gauge() {
        let pool = BlockPool::new(8);
        assert_eq!(pool.target_blocks(), 8);
        let mut a = pool.lease();
        a.vals.extend_from_slice(&[1.0, 2.0]);
        a.offsets.push(2);
        a.nnz.push(2);
        a.bytes = 8;
        let cap = a.vals.capacity();
        assert_eq!(pool.leased(), 1);
        pool.give_back(a);
        assert_eq!(pool.leased(), 0);
        let b = pool.lease();
        // recycled: contents reset, allocation kept
        assert!(b.vals.is_empty());
        assert_eq!(b.offsets, vec![0]);
        assert_eq!(b.bytes, 0);
        assert_eq!(b.rows(), 0);
        assert!(b.vals.capacity() >= cap);
        pool.check_invariants().unwrap();
        pool.give_back(b);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn instrumented_pool_records_latencies() {
        let reg = Registry::new();
        let obs = PoolObs::register(&reg, 2);
        let pool = BlockPool::with_obs(4, obs.clone());
        let a = pool.lease();
        let b = pool.lease();
        pool.give_back(a);
        pool.give_back(b);
        assert_eq!(obs.lease_seconds.snapshot().count(), 2);
        assert_eq!(obs.give_back_seconds.snapshot().count(), 2);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn shared_blocks_free_only_on_last_release() {
        let pool = BlockPool::new(4);
        let b = pool.lease();
        let id = b.id;
        pool.share(id); // a prefix-store entry takes a reference
        pool.share(id); // a second sequence attaches
        let arc = Arc::new(b);
        let arc2 = arc.clone();
        let arc3 = arc.clone();
        assert_eq!(pool.leased(), 1); // gauge counts unique live ids
        pool.release_shared(arc2);
        assert_eq!(pool.leased(), 1);
        pool.release_shared(arc3);
        assert_eq!(pool.leased(), 1);
        pool.release_shared(arc); // last holder: id frees, storage recycles
        assert_eq!(pool.leased(), 0);
        pool.check_invariants().unwrap();
        let c = pool.lease();
        assert_eq!(c.rows(), 0);
        pool.give_back(c);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn leases_are_elastic_past_target() {
        let pool = BlockPool::new(1);
        let a = pool.lease();
        let b = pool.lease(); // past target: still succeeds
        assert_eq!(pool.leased(), 2);
        assert_ne!(a.id, b.id);
        pool.give_back(a);
        pool.give_back(b);
        assert_eq!(pool.leased(), 0);
        pool.check_invariants().unwrap();
    }
}
