//! Block geometry, budget/accounting helpers, and the per-stream
//! [`BlockTable`] mapping a sequence's rows onto pool blocks.

use std::sync::Arc;

use crate::sparse::memory::dense_vector_bytes;
use crate::sparse::StorageMode;

use super::{BlockBuf, BlockPool};

/// Fixed block shape every cache stream of one pool shares.
///
/// A block holds `block_tokens` rows of ONE stream — either winnowed CSR
/// rows of one (layer, kv-head) key/value store, or dense recency-ring
/// rows.  Sparse rows are lane-padded exactly like
/// [`crate::sparse::SparseStore::with_lanes`] pads them, so a block's
/// float capacity is `block_tokens` multiples of the padded row stride —
/// the lane-multiple constraint that keeps the per-block CSR walks
/// tail-free.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockGeometry {
    /// Rows (tokens) per block, >= 1.
    pub block_tokens: usize,
    /// Head dimension of the streams this pool serves.
    pub d_head: usize,
    /// Lane multiple sparse rows are padded to, >= 1.
    pub lanes: usize,
}

impl BlockGeometry {
    pub fn new(block_tokens: usize, d_head: usize, lanes: usize) -> BlockGeometry {
        BlockGeometry { block_tokens: block_tokens.max(1), d_head, lanes: lanes.max(1) }
    }

    /// Worst-case padded width of one sparse row (`k <= d_head` padded up
    /// to the lane multiple).
    pub fn slot_stride(&self) -> usize {
        self.d_head.div_ceil(self.lanes) * self.lanes
    }

    /// Worst-case float capacity of a sparse block.
    pub fn sparse_float_capacity(&self) -> usize {
        self.block_tokens * self.slot_stride()
    }

    /// Float count of a dense-ring block (whole `d_head` rows).
    pub fn dense_floats(&self) -> usize {
        self.block_tokens * self.d_head
    }
}

/// Budget-model bytes of one block: `block_tokens` rows at the larger of
/// the Eq. 1 sparse-vector rate at compression `k` and the dense f16 row
/// rate (a block is either sparse or ring; admission sizes for the
/// worse).  The *accounted* bytes of a leased block charge per-row real
/// nnz (see [`super::paged_cache::PagedRows`]) and are therefore `<=`
/// this bound.
pub fn block_bytes(block_tokens: usize, d_head: usize, mode: StorageMode, k: usize) -> usize {
    block_tokens.max(1) * mode.vector_bytes(k.min(d_head)).max(dense_vector_bytes(d_head))
}

/// Round a projected byte load up to a whole number of blocks — the
/// block-accounted admission charge on the byte-denominated (PJRT
/// engine) path: a sequence cannot hold a fraction of a block.
pub fn block_ceil_bytes(bytes: usize, block_b: usize) -> usize {
    if block_b == 0 {
        return bytes;
    }
    bytes.div_ceil(block_b) * block_b
}

/// Pool sizing: blocks a `mem_budget` buys at the model-wide worst-case
/// block rate (compression `k`, storage `mode`).  `mem_budget == 0`
/// means unbounded and maps to `usize::MAX`; a non-zero budget always
/// yields at least one block (the scheduler's "single over-budget
/// sequence still runs" elasticity).
pub fn pool_blocks_for_budget(
    mem_budget: usize,
    block_tokens: usize,
    d_head: usize,
    mode: StorageMode,
    k: usize,
) -> usize {
    if mem_budget == 0 {
        return usize::MAX;
    }
    (mem_budget / block_bytes(block_tokens, d_head, mode, k)).max(1)
}

/// Blocks a sequence with `tokens` cached tokens holds across the whole
/// model — the analytic admission/accounting rate.  Exact, not an
/// estimate: every (layer, kv-head) stream of a sequence evicts in
/// lockstep, each holds `ceil(buffer / bt)` ring blocks (leased up front
/// at construction) plus `ceil(max(tokens - buffer, 0) / bt)` sparse
/// blocks, and there are `2 * n_layers * n_kv_heads` streams (keys and
/// values).
pub fn seq_blocks(
    tokens: usize,
    buffer: usize,
    block_tokens: usize,
    n_layers: usize,
    n_kv_heads: usize,
) -> usize {
    let bt = block_tokens.max(1);
    let ring = buffer.div_ceil(bt);
    let sparse = tokens.saturating_sub(buffer).div_ceil(bt);
    2 * n_layers * n_kv_heads * (ring + sparse)
}

/// One block-table slot: a block this table owns outright, or a
/// refcounted view of a block shared with a prefix-store entry and/or
/// other sequences.  Sharing is copy-on-write by construction — shared
/// blocks are never mutated: appends only ever target the last slot,
/// the still-filling tail is always `Owned` (a table that attaches a
/// partial prefix tail copies it into a fresh lease — the mandatory
/// tail fork), and full blocks are immutable once written.
pub enum Slot {
    Owned(BlockBuf),
    Shared(Arc<BlockBuf>),
}

impl Slot {
    /// Read access, uniform across ownership.
    pub fn buf(&self) -> &BlockBuf {
        match self {
            Slot::Owned(b) => b,
            Slot::Shared(b) => b,
        }
    }
}

/// One stream's blocks, in row order: the storage-owning half of the
/// paged cache.  Dropping the table gives every owned block back to its
/// pool and drops one reference per shared block (the pool's lease
/// gauge falls only when a block's last holder lets go).
pub struct BlockTable {
    pool: Arc<BlockPool>,
    slots: Vec<Slot>,
    /// Cached block-id row, maintained on every push, so hot-path
    /// readers borrow it instead of collecting a fresh vec per call.
    ids: Vec<u32>,
}

impl BlockTable {
    pub fn new(pool: Arc<BlockPool>) -> BlockTable {
        BlockTable { pool, slots: Vec::new(), ids: Vec::new() }
    }

    /// Lease one more owned block from the pool and return it for
    /// filling.
    pub fn push_block(&mut self) -> &mut BlockBuf {
        let b = self.pool.lease();
        self.ids.push(b.id);
        self.slots.push(Slot::Owned(b));
        match self.slots.last_mut() {
            Some(Slot::Owned(b)) => b,
            // lint: allow(panic, "the slot pushed on the previous line is always Some(Owned)")
            _ => panic!("push_block: freshly pushed owned slot missing"),
        }
    }

    /// Attach a shared (prefix-cached) block: takes one pool reference
    /// for this table and appends the block read-only.
    pub fn push_shared(&mut self, b: Arc<BlockBuf>) {
        self.pool.share(b.id);
        self.ids.push(b.id);
        self.slots.push(Slot::Shared(b));
    }

    /// Convert block `i` to shared form in place and hand out a clone
    /// holding its own pool reference (the prefix-store side).  The
    /// table keeps reading the block exactly as before; it just loses
    /// the right to mutate it — callers only share full, immutable
    /// blocks.
    pub fn share_block(&mut self, i: usize) -> Arc<BlockBuf> {
        // lint: allow(indexing, "callers derive i from rows/block_tokens over this table's own row count")
        let slot = &mut self.slots[i];
        let arc = match slot {
            Slot::Shared(a) => a.clone(),
            Slot::Owned(buf) => {
                let id = buf.id;
                let owned = std::mem::replace(buf, BlockBuf::fresh(id));
                let a = Arc::new(owned);
                *slot = Slot::Shared(a.clone());
                a
            }
        };
        self.pool.share(arc.id);
        arc
    }

    pub fn slots(&self) -> &[Slot] {
        &self.slots
    }

    /// Read access to block `i`, uniform across ownership.
    pub fn buf(&self, i: usize) -> &BlockBuf {
        // lint: allow(indexing, "callers derive i from rows/block_tokens over this table's own row count; tests/pool.rs locks the geometry")
        self.slots[i].buf()
    }

    /// Mutable access to the tail block — `None` when the table is
    /// empty or the tail is shared (callers then fork by pushing a
    /// fresh owned block instead of mutating).
    pub fn last_mut(&mut self) -> Option<&mut BlockBuf> {
        match self.slots.last_mut() {
            Some(Slot::Owned(b)) => Some(b),
            _ => None,
        }
    }

    /// Mutable access to block `i` — ring tables only, which are
    /// all-Owned by construction (sharing extracts only retired sparse
    /// prefixes; ring rows copy instead).
    pub fn get_mut(&mut self, i: usize) -> &mut BlockBuf {
        // lint: allow(indexing, "callers derive i from rows/block_tokens over this table's own row count; tests/pool.rs locks the geometry")
        match &mut self.slots[i] {
            Slot::Owned(b) => b,
            // lint: allow(panic, "ring tables never hold shared slots (attach copies ring rows into owned leases); a violation is a logic bug worth dying loudly for under the supervisor")
            Slot::Shared(_) => panic!("get_mut on a shared block"),
        }
    }

    /// Block count.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// The sequence's block-table row: pool block ids in stream order
    /// (borrowed — no per-call allocation).
    pub fn block_ids(&self) -> &[u32] {
        &self.ids
    }

    /// Accounted (Eq. 1) bytes across all blocks.
    pub fn total_bytes(&self) -> usize {
        self.slots.iter().map(|s| s.buf().bytes).sum()
    }
}

impl Drop for BlockTable {
    fn drop(&mut self) {
        for s in self.slots.drain(..) {
            match s {
                Slot::Owned(b) => self.pool.give_back(b),
                Slot::Shared(a) => self.pool.release_shared(a),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_lane_multiple() {
        let g = BlockGeometry::new(16, 12, 8);
        assert_eq!(g.slot_stride(), 16); // 12 padded to 8-lane multiple
        assert_eq!(g.sparse_float_capacity(), 16 * 16);
        assert_eq!(g.dense_floats(), 16 * 12);
        assert_eq!(BlockGeometry::new(0, 4, 0).block_tokens, 1);
        assert_eq!(BlockGeometry::new(4, 4, 0).lanes, 1);
    }

    #[test]
    fn block_bytes_takes_worse_of_sparse_and_dense() {
        // d_head 8, f16: dense row = 16 B; k=2 sparse vector = 2*3+2 = 8 B
        assert_eq!(block_bytes(4, 8, StorageMode::F16, 2), 4 * 16);
        // k=8 sparse vector = 8*3+2 = 26 B > dense 16 B
        assert_eq!(block_bytes(4, 8, StorageMode::F16, 8), 4 * 26);
        // k clamps to d_head
        assert_eq!(
            block_bytes(4, 8, StorageMode::F16, 99),
            block_bytes(4, 8, StorageMode::F16, 8)
        );
    }

    #[test]
    fn block_ceil_rounds_up_to_whole_blocks() {
        assert_eq!(block_ceil_bytes(0, 64), 0);
        assert_eq!(block_ceil_bytes(1, 64), 64);
        assert_eq!(block_ceil_bytes(64, 64), 64);
        assert_eq!(block_ceil_bytes(65, 64), 128);
        assert_eq!(block_ceil_bytes(100, 0), 100); // degenerate guard
    }

    #[test]
    fn budget_sizing() {
        assert_eq!(pool_blocks_for_budget(0, 16, 8, StorageMode::F16, 4), usize::MAX);
        let bb = block_bytes(16, 8, StorageMode::F16, 4);
        assert_eq!(pool_blocks_for_budget(10 * bb + 1, 16, 8, StorageMode::F16, 4), 10);
        // a budget smaller than one block still buys one (elastic floor)
        assert_eq!(pool_blocks_for_budget(1, 16, 8, StorageMode::F16, 4), 1);
    }

    #[test]
    fn seq_blocks_counts_ring_and_sparse_streams() {
        // buffer 3, bt 2 -> 2 ring blocks per stream; 7 tokens -> 4
        // sparse rows -> 2 sparse blocks per stream; 2 layers x 1 kv head
        // x 2 (k+v) = 4 streams
        assert_eq!(seq_blocks(7, 3, 2, 2, 1), 4 * (2 + 2));
        // all-dense phase: no sparse blocks yet
        assert_eq!(seq_blocks(3, 3, 2, 2, 1), 4 * 2);
        // zero-buffer config: everything sparse, no ring blocks
        assert_eq!(seq_blocks(5, 0, 2, 1, 1), 2 * 3);
        assert_eq!(seq_blocks(0, 0, 2, 1, 1), 0);
    }
}
