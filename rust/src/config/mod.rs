//! Configuration types for the serving stack and experiments.

use crate::sparse::StorageMode;
use crate::util::json::Json;

/// Model hyper-parameters (mirrors `python/compile/common.ModelConfig`;
/// parsed from the weights-container meta blob / manifest).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub rope_theta: f32,
    pub norm_eps: f32,
}

impl ModelConfig {
    pub fn group(&self) -> usize {
        debug_assert_eq!(self.n_q_heads % self.n_kv_heads, 0);
        self.n_q_heads / self.n_kv_heads
    }

    pub fn from_json(j: &Json) -> crate::Result<ModelConfig> {
        let get_n = |k: &str| -> crate::Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("config missing field {k}"))
        };
        Ok(ModelConfig {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("config missing name"))?
                .to_string(),
            d_model: get_n("d_model")?,
            n_layers: get_n("n_layers")?,
            n_q_heads: get_n("n_q_heads")?,
            n_kv_heads: get_n("n_kv_heads")?,
            d_head: get_n("d_head")?,
            d_ff: get_n("d_ff")?,
            vocab: get_n("vocab")?,
            rope_theta: j.get("rope_theta").and_then(Json::as_f64).unwrap_or(10000.0) as f32,
            norm_eps: j.get("norm_eps").and_then(Json::as_f64).unwrap_or(1e-5) as f32,
        })
    }
}

/// Serving engine configuration (coordinator defaults).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Model artifact name (e.g. "swan-nano-gqa").
    pub model: String,
    /// SWAN compression: retained dims on eviction.
    pub k_active: usize,
    /// Dense buffer tokens.
    pub buffer: usize,
    /// Sparse value storage.
    pub mode: StorageMode,
    /// Max concurrent sequences in a decode batch.
    pub max_batch: usize,
    /// Max new tokens per request unless the request overrides.
    pub max_new_tokens: usize,
    /// KV-cache memory budget (bytes) for admission control; 0 = unlimited.
    /// With `shards > 1` this is the *fleet* budget, split evenly across
    /// shards at launch (in pipeline mode: across pipeline *groups*, each
    /// stage's share then proportional to its layer count).
    pub mem_budget: usize,
    /// Admission lookahead window: under a tight `mem_budget`, the first
    /// admissible request among the first N pending is admitted instead of
    /// letting one oversized head block the queue (1 = strict FIFO).
    pub admit_lookahead: usize,
    /// Serve with the dense baseline instead of SWAN (for A/B runs).
    pub dense_baseline: bool,
    /// Worker threads **per shard** for the iteration-level decode
    /// fan-out (0 = serial single-thread decode; results are identical
    /// either way).
    pub decode_workers: usize,
    /// Engine shards behind the front-end router (>= 1); each shard runs
    /// its own thread, scheduler, worker pool and KV budget slice.
    pub shards: usize,
    /// Pipeline depth: with `pipeline > 1` the fleet runs in layer-sharded
    /// mode — the `shards` slots are grouped into `shards / pipeline`
    /// pipeline *groups* of `pipeline` stages each, every stage owning a
    /// contiguous layer range of the model and sequences flowing through
    /// the group via cross-stage activation handoff.  `1` = classic
    /// data-parallel shards (each engine owns the whole model).
    pub pipeline: usize,
    /// Placement policy name for the router (see
    /// `shard::balance::POLICY_NAMES`): "round-robin", "least-queued" or
    /// "mem-aware".
    pub balance: String,
    /// Compute kernel path ("auto", "scalar" or "avx2") — pinned
    /// process-wide at startup via [`crate::simd::init_from_name`]; every
    /// shard's engines, worker pools and cache policies dispatch through
    /// the same selection.
    pub kernels: String,
    /// TCP bind address for `swan serve`.
    pub bind: String,
    /// Serve KV storage out of a paged block pool (`crate::pool`): the
    /// native pipeline path stores every sequence's winnowed rows and
    /// ring tail in fixed-size leased blocks, admission counts blocks
    /// instead of raw bytes, and over-budget decode growth preempts the
    /// youngest sequence block-granularly instead of rejecting.  The
    /// PJRT engine path keeps per-sequence caches but rounds admission
    /// projections to whole allocation granules.  Decode output is
    /// bit-identical with the pool on or off.
    pub pool: bool,
    /// Rows (tokens) per pool block, >= 1.
    pub block_tokens: usize,
    /// Cross-request prefix caching (`--prefix-cache` / `SET prefix
    /// on|off`): pipeline groups index retired prompts' full-block
    /// prefixes in a per-group [`crate::prefix::PrefixTree`] and serve
    /// later prompts that share a prefix by attaching the cached blocks
    /// copy-on-write, running prefill only over the uncached suffix.
    /// Implies the block pool on the pipeline path; ignored (with a
    /// warning) under `--dense-baseline`.
    pub prefix: bool,
    /// How long a draining shard (`DRAIN <id>` / `SET shards <n>`
    /// scale-down) waits for in-flight work to finish before migrating
    /// the stragglers to healthy shards through the exact-recovery path.
    pub drain_timeout_ms: u64,
}

impl ServeConfig {
    /// Hard per-request ceiling on `max_new`: requests may ask for up to
    /// 8x the configured default.  Engines clamp at submission and
    /// record the original ask in `RequestStats::clamped_from`, and the
    /// TCP front-end surfaces it on the reply line (`clamped=<cap>`) —
    /// the clamp is enforced, never silent.
    pub fn max_new_hard_cap(&self) -> usize {
        self.max_new_tokens.max(1) * 8
    }
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            model: "swan-nano-gqa".into(),
            k_active: 32,
            buffer: 64,
            mode: StorageMode::F16,
            max_batch: 8,
            max_new_tokens: 64,
            mem_budget: 0,
            admit_lookahead: crate::coordinator::scheduler::DEFAULT_LOOKAHEAD,
            dense_baseline: false,
            decode_workers: 0,
            shards: 1,
            pipeline: 1,
            balance: "round-robin".into(),
            kernels: "auto".into(),
            bind: "127.0.0.1:7877".into(),
            pool: false,
            block_tokens: 16,
            prefix: false,
            drain_timeout_ms: 5000,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_config_from_json() {
        let j = Json::parse(
            r#"{"name":"m","d_model":256,"n_layers":4,"n_q_heads":4,
                "n_kv_heads":1,"d_head":64,"d_ff":1024,"vocab":96,
                "rope_theta":10000.0,"norm_eps":1e-5}"#,
        )
        .unwrap();
        let c = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c.group(), 4);
        assert_eq!(c.d_head, 64);
    }

    #[test]
    fn hard_cap_is_8x_default_and_never_zero() {
        let cfg = ServeConfig::default();
        assert_eq!(cfg.max_new_hard_cap(), cfg.max_new_tokens * 8);
        let z = ServeConfig { max_new_tokens: 0, ..Default::default() };
        assert_eq!(z.max_new_hard_cap(), 8);
    }

    #[test]
    fn missing_field_is_error() {
        let j = Json::parse(r#"{"name":"m"}"#).unwrap();
        assert!(ModelConfig::from_json(&j).is_err());
    }
}
