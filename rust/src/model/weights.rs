//! Reader for the binary tensor container written by
//! `python/compile/common.write_tensors` (see that module for the layout).

use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context};

use crate::config::ModelConfig;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"SWANWTS1";

/// A named tensor: f32 or i32 data plus shape.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Clone, Debug)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => bail!("tensor is i32, expected f32"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => bail!("tensor is f32, expected i32"),
        }
    }
}

/// A loaded tensor container: model meta + named tensors.
pub struct WeightFile {
    pub meta: Json,
    pub tensors: BTreeMap<String, Tensor>,
}

impl WeightFile {
    pub fn load(path: &Path) -> anyhow::Result<WeightFile> {
        let mut f = std::fs::File::open(path)
            .with_context(|| format!("opening weights {}", path.display()))?;
        let mut buf = Vec::new();
        f.read_to_end(&mut buf)?;
        Self::parse(&buf).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn parse(buf: &[u8]) -> anyhow::Result<WeightFile> {
        let mut pos = 0usize;
        let take = |pos: &mut usize, n: usize| -> anyhow::Result<&[u8]> {
            if *pos + n > buf.len() {
                bail!("truncated container at {pos}");
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        if take(&mut pos, 8)? != MAGIC {
            bail!("bad magic");
        }
        let jlen = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let meta_raw = std::str::from_utf8(take(&mut pos, jlen)?)?.to_string();
        let meta = Json::parse(&meta_raw).map_err(|e| anyhow::anyhow!("meta json: {e}"))?;
        let count = u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let nlen = u16::from_le_bytes(take(&mut pos, 2)?.try_into().unwrap()) as usize;
            let name = std::str::from_utf8(take(&mut pos, nlen)?)?.to_string();
            let hdr = take(&mut pos, 2)?;
            let (dtype, ndim) = (hdr[0], hdr[1] as usize);
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(u32::from_le_bytes(take(&mut pos, 4)?.try_into().unwrap()) as usize);
            }
            let numel: usize = shape.iter().product::<usize>().max(if ndim == 0 { 1 } else { 0 });
            let raw = take(&mut pos, numel * 4)?;
            let data = match dtype {
                0 => TensorData::F32(
                    raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
                ),
                1 => TensorData::I32(
                    raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
                ),
                d => bail!("unknown dtype code {d}"),
            };
            tensors.insert(name, Tensor { shape, data });
        }
        Ok(WeightFile { meta, tensors })
    }

    pub fn config(&self) -> anyhow::Result<ModelConfig> {
        ModelConfig::from_json(&self.meta)
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing tensor '{name}'"))
    }

    pub fn f32(&self, name: &str) -> anyhow::Result<&[f32]> {
        self.get(name)?.as_f32()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a container in the python layout and parse it back.
    fn build_container() -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        let meta = br#"{"name": "t", "x": 1}"#;
        buf.extend_from_slice(&(meta.len() as u32).to_le_bytes());
        buf.extend_from_slice(meta);
        buf.extend_from_slice(&2u32.to_le_bytes());
        // tensor "a": f32 [2,2]
        buf.extend_from_slice(&1u16.to_le_bytes());
        buf.push(b'a');
        buf.push(0); // f32
        buf.push(2); // ndim
        buf.extend_from_slice(&2u32.to_le_bytes());
        buf.extend_from_slice(&2u32.to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        // tensor "b": i32 [3]
        buf.extend_from_slice(&1u16.to_le_bytes());
        buf.push(b'b');
        buf.push(1); // i32
        buf.push(1);
        buf.extend_from_slice(&3u32.to_le_bytes());
        for v in [7i32, 8, 9] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        buf
    }

    #[test]
    fn parses_container() {
        let wf = WeightFile::parse(&build_container()).unwrap();
        assert_eq!(wf.meta.get("x").unwrap().as_f64(), Some(1.0));
        assert_eq!(wf.f32("a").unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(wf.get("b").unwrap().as_i32().unwrap(), &[7, 8, 9]);
        assert_eq!(wf.get("a").unwrap().shape, vec![2, 2]);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut buf = build_container();
        buf[0] = b'X';
        assert!(WeightFile::parse(&buf).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let buf = build_container();
        assert!(WeightFile::parse(&buf[..buf.len() - 3]).is_err());
    }

    #[test]
    fn missing_tensor_is_error() {
        let wf = WeightFile::parse(&build_container()).unwrap();
        assert!(wf.f32("nope").is_err());
        assert!(wf.get("a").unwrap().as_i32().is_err());
    }
}
