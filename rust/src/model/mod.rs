//! Rust-native model path: loads `artifacts/weights_*.bin` (trained +
//! calibrated by the python build step) and runs the transformer forward
//! with pluggable KV-cache policies.  Golden-verified against the python
//! model (`tests/golden.rs`).

pub mod generate;
pub mod transformer;
pub mod weights;

pub use transformer::{Prefill, SequenceState, StageInput, SwanModel};
pub use weights::WeightFile;
