//! Rust-native SWAN transformer.
//!
//! Loads the python-trained weights (original + absorbed) and runs:
//!
//! * [`SwanModel::prefill`] — exact (dense, rotated-space) prompt
//!   processing.  Policy-independent, so the experiment harness computes it
//!   once per prompt and replays it into any number of cache policies.
//! * [`SwanModel::decode_step`] — one autoregressive step through a
//!   [`SequenceState`] whose per-(layer, kv-head) caches are any
//!   [`CachePolicy`] (SWAN, dense, H2O, StreamingLLM, KIVI).
//!
//! The rotation is carried in the weights themselves: Ŵ_V / Ŵ_O are the
//! absorbed matrices (§4.2) and P_QK is applied at runtime after RoPE —
//! exactly the structure of the serving graphs in `python/compile/model.py`.

use anyhow::Context;

use crate::config::ModelConfig;
use crate::kvcache::{CachePolicy, PolicyKind};
use crate::model::weights::WeightFile;
use crate::swan::batch::WorkerPool;
use crate::swan::projection::{ProjectionSet, ProjectionVariant};
use crate::tensor::ops::{gelu, rmsnorm, vecmat};
use crate::tensor::rope::apply_rope;
use crate::util::Pcg64;

/// Per-layer weights (rotated-space serving set + originals for
/// re-absorption under projection ablations).
#[derive(Clone)]
pub struct LayerWeights {
    pub attn_norm: Vec<f32>,
    pub wq: Vec<f32>,     // [d, nq*dh]
    pub wk: Vec<f32>,     // [d, nkv*dh]
    pub wv_hat: Vec<f32>, // [d, nkv*dh] absorbed
    pub wo_hat: Vec<f32>, // [nq*dh, d] absorbed
    pub mlp_norm: Vec<f32>,
    pub w1: Vec<f32>, // [d, dff]
    pub w2: Vec<f32>, // [dff, d]
    // originals (ablation support)
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
}

pub struct SwanModel {
    pub cfg: ModelConfig,
    pub embed: Vec<f32>, // [vocab, d]
    pub layers: Vec<LayerWeights>,
    pub final_norm: Vec<f32>,
    pub lm_head: Vec<f32>, // [d, vocab]
    /// Runtime rotation for Q/K (post-RoPE).
    pub proj: ProjectionSet,
}

/// Exact prefill results (policy-independent).
///
/// For a full-model prefill the outer index runs over all layers; a
/// pipeline stage's [`SwanModel::prefill_layers`] returns the same shape
/// indexed by layer *within its range* (and leaves `logits` empty — only
/// the last stage computes them via [`SwanModel::prefill_logits`]).
pub struct Prefill {
    /// khat[layer][kv_head] flat [T, d_h], oldest first.
    pub khat: Vec<Vec<Vec<f32>>>,
    pub vhat: Vec<Vec<Vec<f32>>>,
    /// Cumulative attention mass each position received (for H2O seeding):
    /// mass[layer][kv_head][t].
    pub mass: Vec<Vec<Vec<f32>>>,
    /// Logits at the last prompt position.
    pub logits: Vec<f32>,
    /// Prompt length.
    pub len: usize,
}

/// Decode-step input for one pipeline stage: the first stage embeds the
/// sampled tokens, every later stage continues from the hidden rows the
/// previous stage handed off.
pub enum StageInput<'a> {
    /// One sampled token per sequence (stage 0).
    Tokens(&'a [u32]),
    /// One `[d_model]` hidden row per sequence (stages 1..).
    Hidden(Vec<Vec<f32>>),
}

/// One live sequence: per-(layer, kv-head) cache policies + position.
pub struct SequenceState {
    pub caches: Vec<Box<dyn CachePolicy>>,
    pub pos: usize,
    n_kv: usize,
}

impl SequenceState {
    pub fn new(model: &SwanModel, kind: PolicyKind) -> SequenceState {
        SequenceState::for_layers(model, kind, model.cfg.n_layers)
    }

    /// State covering only `n_layers` of the model — a pipeline stage
    /// builds one per sequence for its own layer range; cache index
    /// `(layer_within_range) * n_kv + head`.
    pub fn for_layers(model: &SwanModel, kind: PolicyKind, n_layers: usize) -> SequenceState {
        let cfg = &model.cfg;
        let caches = (0..n_layers * cfg.n_kv_heads)
            .map(|_| kind.build(cfg.d_head))
            .collect();
        SequenceState { caches, pos: 0, n_kv: cfg.n_kv_heads }
    }

    /// [`SequenceState::for_layers`] with a caller-supplied cache
    /// factory, for policies [`PolicyKind`] cannot describe — the paged
    /// pool path builds [`crate::pool::PagedSwanCache`]s here, each
    /// closure call leasing from the stage's shared block pool.
    pub fn for_layers_with(
        model: &SwanModel,
        n_layers: usize,
        mut factory: impl FnMut() -> Box<dyn CachePolicy>,
    ) -> SequenceState {
        let cfg = &model.cfg;
        let caches = (0..n_layers * cfg.n_kv_heads).map(|_| factory()).collect();
        SequenceState { caches, pos: 0, n_kv: cfg.n_kv_heads }
    }

    /// Seed the caches from an exact prefill.
    pub fn load_prefill(&mut self, pf: &Prefill) {
        let d = if pf.khat.is_empty() || pf.khat[0].is_empty() || pf.len == 0 {
            0
        } else {
            pf.khat[0][0].len() / pf.len
        };
        for (l, layer_k) in pf.khat.iter().enumerate() {
            for (h, kf) in layer_k.iter().enumerate() {
                let cache = &mut self.caches[l * self.n_kv + h];
                cache.load_history(kf, &pf.vhat[l][h], d, Some(&pf.mass[l][h]));
            }
        }
        self.pos = pf.len;
    }

    pub fn cache(&self, layer: usize, kv_head: usize) -> &dyn CachePolicy {
        self.caches[layer * self.n_kv + kv_head].as_ref()
    }

    /// Total cache bytes across all layers/heads.
    pub fn storage_bytes(&self) -> usize {
        self.caches.iter().map(|c| c.storage_bytes()).sum()
    }
}

impl SwanModel {
    /// Load from a weights container, optionally applying a projection
    /// ablation (Table 3): non-`Calibrated` variants re-absorb Ŵ_V/Ŵ_O
    /// from the originals with the ablated P_VO.
    pub fn load(wf: &WeightFile, variant: ProjectionVariant, seed: u64) -> anyhow::Result<SwanModel> {
        let cfg = wf.config().context("weights meta")?;
        let (nl, nkv, dh) = (cfg.n_layers, cfg.n_kv_heads, cfg.d_head);

        // calibrated projections from the artifact
        let mut proj = ProjectionSet::identity(nl, nkv, dh);
        for l in 0..nl {
            let pqk = wf.f32(&format!("l{l}.p_qk"))?;
            let pvo = wf.f32(&format!("l{l}.p_vo"))?;
            for h in 0..nkv {
                proj.p_qk[l][h] = pqk[h * dh * dh..(h + 1) * dh * dh].to_vec();
                proj.p_vo[l][h] = pvo[h * dh * dh..(h + 1) * dh * dh].to_vec();
            }
        }
        let proj = proj.ablate(variant, seed);

        let mut layers = Vec::with_capacity(nl);
        for l in 0..nl {
            let mut lw = LayerWeights {
                attn_norm: wf.f32(&format!("l{l}.attn_norm"))?.to_vec(),
                wq: wf.f32(&format!("l{l}.wq"))?.to_vec(),
                wk: wf.f32(&format!("l{l}.wk"))?.to_vec(),
                wv_hat: wf.f32(&format!("l{l}.wv_hat"))?.to_vec(),
                wo_hat: wf.f32(&format!("l{l}.wo_hat"))?.to_vec(),
                mlp_norm: wf.f32(&format!("l{l}.mlp_norm"))?.to_vec(),
                w1: wf.f32(&format!("l{l}.w1"))?.to_vec(),
                w2: wf.f32(&format!("l{l}.w2"))?.to_vec(),
                wv: wf.f32(&format!("l{l}.wv"))?.to_vec(),
                wo: wf.f32(&format!("l{l}.wo"))?.to_vec(),
            };
            if variant != ProjectionVariant::Calibrated {
                absorb(&cfg, &mut lw, &proj.p_vo[l]);
            }
            layers.push(lw);
        }

        Ok(SwanModel {
            embed: wf.f32("embed")?.to_vec(),
            final_norm: wf.f32("final_norm")?.to_vec(),
            lm_head: wf.f32("lm_head")?.to_vec(),
            layers,
            proj,
            cfg,
        })
    }

    /// Exact rotated-space prefill over `tokens` (policy-independent).
    ///
    /// Serial entry point: runs [`SwanModel::prefill_with_pool`] on a
    /// thread-local serial pool, exactly like [`SwanModel::decode_step`]
    /// wraps the batched decode — one implementation for both modes is
    /// what makes the serial≡parallel determinism test meaningful.
    pub fn prefill(&self, tokens: &[u32]) -> Prefill {
        thread_local! {
            static SERIAL_POOL: std::cell::RefCell<WorkerPool> =
                std::cell::RefCell::new(WorkerPool::serial());
        }
        SERIAL_POOL.with(|pool| self.prefill_with_pool(tokens, &mut pool.borrow_mut()))
    }

    /// Prefill with the per-layer work fanned across `pool`: embed, run
    /// every layer ([`SwanModel::prefill_layers`]), project the last
    /// position to logits ([`SwanModel::prefill_logits`]).  A pipeline
    /// fleet runs the same three pieces split across stages, so the
    /// composition here is what makes stage counts bit-identical.
    pub fn prefill_with_pool(&self, tokens: &[u32], pool: &mut WorkerPool) -> Prefill {
        let mut h = self.embed_prompt(tokens);
        let mut pf = self.prefill_layers(&mut h, 0..self.cfg.n_layers, pool);
        pf.logits = self.prefill_logits(&h);
        pf
    }

    /// Embed a prompt into its initial hidden rows (`[T, d_model]` flat).
    pub fn embed_prompt(&self, tokens: &[u32]) -> Vec<f32> {
        let d = self.cfg.d_model;
        let mut h: Vec<f32> = Vec::with_capacity(tokens.len() * d);
        for &tok in tokens {
            h.extend_from_slice(&self.embed[tok as usize * d..(tok as usize + 1) * d]);
        }
        h
    }

    /// Run prefill through `layers` only, transforming `h` (`[T, d_model]`
    /// flat hidden rows) in place and returning the range's rotated
    /// (k̂, v̂) streams + attention mass, indexed by layer *within the
    /// range* (`logits` left empty).  Three phases per layer, each task
    /// writing only its own buffers, so the result is bit-identical to the
    /// serial loop for any pool size:
    ///
    /// 1. projections + RoPE + rotation — one task per token (working
    ///    buffers live in the worker's [`AttentionScratch`] `tmp`);
    /// 2. causal attention — one task per kv-head: the task exclusively
    ///    owns that group's attention-mass row and output buffer and
    ///    walks its tokens oldest-first, so per-cell accumulation order
    ///    matches the serial loop exactly;
    /// 3. output projection + residual + MLP — one task per token.
    pub fn prefill_layers(
        &self,
        h: &mut [f32],
        layers: std::ops::Range<usize>,
        pool: &mut WorkerPool,
    ) -> Prefill {
        let cfg = &self.cfg;
        let (d, dh, nq, nkv, g) =
            (cfg.d_model, cfg.d_head, cfg.n_q_heads, cfg.n_kv_heads, cfg.group());
        let t = h.len() / d;
        debug_assert_eq!(h.len(), t * d);
        let (dff, theta, eps) = (cfg.d_ff, cfg.rope_theta, cfg.norm_eps);
        let scale = 1.0 / (dh as f32).sqrt();
        let ks = crate::simd::active();
        let n_range = layers.len();

        let mut khat = vec![vec![Vec::new(); nkv]; n_range];
        let mut vhat = vec![vec![Vec::new(); nkv]; n_range];
        let mut mass = vec![vec![vec![0.0f32; t]; nkv]; n_range];

        /// Phase 1 task: one token's q̂/k̂/v̂ rows.
        struct ProjTask<'a> {
            x: &'a [f32],
            q: &'a mut [f32],
            k: &'a mut [f32],
            v: &'a mut [f32],
            pos: u32,
        }

        /// Phase 2 task: one kv-head group's attention over all tokens.
        struct HeadTask<'a> {
            grp: usize,
            kh: &'a [f32],
            vh: &'a [f32],
            mass: &'a mut [f32],
            /// [t, g, d_h] flat — the group's slice of every token's
            /// attention output row.
            out: Vec<f32>,
        }

        for (li, l) in layers.clone().enumerate() {
            let lw = &self.layers[l];
            // phase 1: per-token projections into rotated q̂ and staging
            // rows for k̂/v̂ ([t, nkv*dh]; distributed to the per-head
            // [t, dh] output layout right after)
            let mut qh = vec![0.0f32; t * nq * dh];
            let mut krows = vec![0.0f32; t * nkv * dh];
            let mut vrows = vec![0.0f32; t * nkv * dh];
            {
                let mut tasks: Vec<ProjTask> = qh
                    .chunks_mut(nq * dh)
                    .zip(krows.chunks_mut(nkv * dh))
                    .zip(vrows.chunks_mut(nkv * dh))
                    .zip(h.chunks(d))
                    .enumerate()
                    .map(|(ti, (((q, k), v), x))| ProjTask { x, q, k, v, pos: ti as u32 })
                    .collect();
                pool.for_each_mut(&mut tasks, |scratch, tk| {
                    // tmp layout: xn [d] | raw [max(nq, nkv) * dh]
                    let need = d + nq.max(nkv) * dh;
                    if scratch.tmp.len() < need {
                        scratch.tmp.resize(need, 0.0);
                    }
                    let (xn, raw) = scratch.tmp.split_at_mut(d);
                    ks.rmsnorm(tk.x, &lw.attn_norm, eps, xn);
                    let qraw = &mut raw[..nq * dh];
                    ks.vecmat(xn, &lw.wq, d, nq * dh, qraw);
                    for j in 0..nq {
                        apply_rope(&mut qraw[j * dh..(j + 1) * dh], tk.pos, theta);
                        self.proj.rotate_qk(
                            l,
                            j / g,
                            &qraw[j * dh..(j + 1) * dh],
                            &mut tk.q[j * dh..(j + 1) * dh],
                        );
                    }
                    let kraw = &mut raw[..nkv * dh];
                    ks.vecmat(xn, &lw.wk, d, nkv * dh, kraw);
                    for hd in 0..nkv {
                        apply_rope(&mut kraw[hd * dh..(hd + 1) * dh], tk.pos, theta);
                        self.proj.rotate_qk(
                            l,
                            hd,
                            &kraw[hd * dh..(hd + 1) * dh],
                            &mut tk.k[hd * dh..(hd + 1) * dh],
                        );
                    }
                    ks.vecmat(xn, &lw.wv_hat, d, nkv * dh, tk.v);
                });
            }
            let kh_l = &mut khat[li];
            let vh_l = &mut vhat[li];
            for hd in 0..nkv {
                kh_l[hd] = vec![0.0; t * dh];
                vh_l[hd] = vec![0.0; t * dh];
                for ti in 0..t {
                    let src = (ti * nkv + hd) * dh;
                    kh_l[hd][ti * dh..(ti + 1) * dh]
                        .copy_from_slice(&krows[src..src + dh]);
                    vh_l[hd][ti * dh..(ti + 1) * dh]
                        .copy_from_slice(&vrows[src..src + dh]);
                }
            }

            // phase 2: causal attention, one task per kv-head group
            let mut gtasks: Vec<HeadTask> = kh_l
                .iter()
                .zip(vh_l.iter())
                .zip(mass[li].iter_mut())
                .enumerate()
                .map(|(grp, ((kh, vh), mass_g))| HeadTask {
                    grp,
                    kh: kh.as_slice(),
                    vh: vh.as_slice(),
                    mass: mass_g.as_mut_slice(),
                    out: vec![0.0f32; t * g * dh],
                })
                .collect();
            pool.for_each_mut(&mut gtasks, |scratch, gt| {
                let scores = &mut scratch.scores;
                for ti in 0..t {
                    for jg in 0..g {
                        let j = gt.grp * g + jg;
                        let q = &qh[(ti * nq + j) * dh..(ti * nq + j + 1) * dh];
                        scores.clear();
                        scores.reserve(ti + 1);
                        let mut m = f32::NEG_INFINITY;
                        for s in 0..=ti {
                            let sc = ks.dot(&gt.kh[s * dh..(s + 1) * dh], q) * scale;
                            m = m.max(sc);
                            scores.push(sc);
                        }
                        ks.softmax_inplace_with_max(scores, m);
                        let o = &mut gt.out[(ti * g + jg) * dh..(ti * g + jg + 1) * dh];
                        o.iter_mut().for_each(|x| *x = 0.0);
                        for s in 0..=ti {
                            gt.mass[s] += scores[s];
                            ks.axpy(scores[s], &gt.vh[s * dh..(s + 1) * dh], o);
                        }
                    }
                }
            });
            let attn_groups: Vec<Vec<f32>> = gtasks.into_iter().map(|gt| gt.out).collect();

            // phase 3: output projection + residual + MLP, one task per token
            let mut otasks: Vec<(usize, &mut [f32])> =
                h.chunks_mut(d).enumerate().collect();
            pool.for_each_mut(&mut otasks, |scratch, task| {
                let ti = task.0;
                let hrow = &mut *task.1;
                // tmp layout: arow [nq*dh] | xn [d] | proj [d] | mid [dff] | back [d]
                let need = nq * dh + 3 * d + dff;
                if scratch.tmp.len() < need {
                    scratch.tmp.resize(need, 0.0);
                }
                let (arow, rest) = scratch.tmp.split_at_mut(nq * dh);
                let (xn, rest) = rest.split_at_mut(d);
                let (proj_out, rest) = rest.split_at_mut(d);
                let (mid, rest) = rest.split_at_mut(dff);
                let back = &mut rest[..d];
                for (grp, gout) in attn_groups.iter().enumerate() {
                    arow[grp * g * dh..(grp + 1) * g * dh]
                        .copy_from_slice(&gout[ti * g * dh..(ti + 1) * g * dh]);
                }
                ks.vecmat(arow, &lw.wo_hat, nq * dh, d, proj_out);
                for (hr, po) in hrow.iter_mut().zip(proj_out.iter()) {
                    *hr += *po;
                }
                ks.rmsnorm(hrow, &lw.mlp_norm, eps, xn);
                ks.vecmat(xn, &lw.w1, d, dff, mid);
                mid.iter_mut().for_each(|m| *m = gelu(*m));
                ks.vecmat(mid, &lw.w2, dff, d, back);
                for (hr, b) in hrow.iter_mut().zip(back.iter()) {
                    *hr += *b;
                }
            });
        }

        Prefill { khat, vhat, mass, logits: Vec::new(), len: t }
    }

    /// Final-norm + lm-head over the last hidden row of a prefill (`h` is
    /// `[T, d_model]` flat, fully transformed by every layer).  Only the
    /// last pipeline stage runs this.
    pub fn prefill_logits(&self, h: &[f32]) -> Vec<f32> {
        let (d, eps) = (self.cfg.d_model, self.cfg.norm_eps);
        let t = h.len() / d;
        let mut xn = vec![0.0f32; d];
        let last = &h[(t - 1) * d..t * d];
        rmsnorm(last, &self.final_norm, eps, &mut xn);
        let mut logits = vec![0.0f32; self.cfg.vocab];
        vecmat(&xn, &self.lm_head, d, self.cfg.vocab, &mut logits);
        logits
    }

    /// One decode step through the sequence's cache policies; returns the
    /// logits for `token`'s successor and advances the state.
    ///
    /// This is the batch-of-one case of [`SwanModel::decode_step_batch`]
    /// run on a serial pool — the single implementation is what makes the
    /// serial-vs-parallel determinism guarantee checkable.
    pub fn decode_step(&self, state: &mut SequenceState, token: u32) -> Vec<f32> {
        // one serial pool per thread, reused across steps so the scratch
        // keeps its capacity and no pool machinery is built per token
        thread_local! {
            static SERIAL_POOL: std::cell::RefCell<WorkerPool> =
                std::cell::RefCell::new(WorkerPool::serial());
        }
        SERIAL_POOL
            .with(|pool| {
                self.decode_step_batch(
                    std::slice::from_mut(state),
                    &[token],
                    &mut pool.borrow_mut(),
                )
            })
            .pop()
            .expect("one sequence in, one logits row out")
    }

    /// One lock-step decode iteration for a batch of sequences: every
    /// sequence advances by one token, layer by layer, with the per-layer
    /// work fanned across `pool`:
    ///
    /// 1. projections + RoPE + rotation — one task per sequence;
    /// 2. attention — one task per `(sequence, kv-head)`; the task owns
    ///    that head's cache `&mut` (disjoint from every other task) and
    ///    attends all query heads of the GQA group through the worker's
    ///    reusable scratch;
    /// 3. cache append + output projection + MLP — one task per sequence.
    ///
    /// Each task writes only its own buffers, so the produced logits are
    /// bit-identical to calling [`SwanModel::decode_step`] per sequence,
    /// for any pool size (`tests/batch_decode.rs`).
    pub fn decode_step_batch(
        &self,
        states: &mut [SequenceState],
        tokens: &[u32],
        pool: &mut WorkerPool,
    ) -> Vec<Vec<f32>> {
        self.decode_step_pipeline(
            states,
            StageInput::Tokens(tokens),
            0..self.cfg.n_layers,
            true,
            pool,
        )
    }

    /// One lock-step decode iteration through `layers` only — the
    /// pipeline-stage form of [`SwanModel::decode_step_batch`] (which is
    /// exactly this call over the full range with token input and logits
    /// output).  `states` must cover `layers.len()` layers (see
    /// [`SequenceState::for_layers`]); positions advance by one per call,
    /// so every stage of a pipeline tracks the same RoPE positions.
    ///
    /// Returns one row per sequence: the final logits when `emit_logits`
    /// (the last stage), otherwise the transformed hidden rows to hand to
    /// the next stage.  Per-layer math and task decomposition are
    /// identical to the full-range call, which is what makes an N-stage
    /// pipeline bit-identical to a single engine.
    pub fn decode_step_pipeline(
        &self,
        states: &mut [SequenceState],
        input: StageInput<'_>,
        layers: std::ops::Range<usize>,
        emit_logits: bool,
        pool: &mut WorkerPool,
    ) -> Vec<Vec<f32>> {
        let cfg = &self.cfg;
        let (d, dh, nq, nkv, g) =
            (cfg.d_model, cfg.d_head, cfg.n_q_heads, cfg.n_kv_heads, cfg.group());

        let rows: Vec<Vec<f32>> = match input {
            StageInput::Tokens(tokens) => {
                assert_eq!(states.len(), tokens.len(), "one token per sequence");
                tokens
                    .iter()
                    // lint: allow(hot_alloc, "the embedding row is copied once per token to seed the owned hidden state that flows through DecodeWork")
                    .map(|&tok| self.embed[tok as usize * d..(tok as usize + 1) * d].to_vec())
                    .collect()
            }
            StageInput::Hidden(rows) => {
                assert_eq!(states.len(), rows.len(), "one hidden row per sequence");
                rows
            }
        };
        let mut works: Vec<DecodeWork> = states
            .iter()
            .zip(rows)
            .map(|(st, h)| DecodeWork {
                h,
                xn: vec![0.0; d],
                qraw: vec![0.0; nq * dh],
                kraw: vec![0.0; nkv * dh],
                vr: vec![0.0; nkv * dh],
                qhat: vec![0.0; nq * dh],
                khat: vec![0.0; nkv * dh],
                attn_out: vec![0.0; nq * dh],
                proj: vec![0.0; d],
                mid: vec![0.0; cfg.d_ff],
                back: vec![0.0; d],
                logits: vec![0.0; if emit_logits { cfg.vocab } else { 0 }],
                pos: st.pos as u32,
            })
            .collect();

        // lint: allow(hot_alloc, "Range<usize>::clone is two usizes on the stack, not a heap allocation")
        for (li, l) in layers.clone().enumerate() {
            let lw = &self.layers[l];
            // 1. per-sequence projections into rotated q̂/k̂/v̂
            pool.for_each_mut(&mut works, |_scratch, w| {
                rmsnorm(&w.h, &lw.attn_norm, cfg.norm_eps, &mut w.xn);
                vecmat(&w.xn, &lw.wq, d, nq * dh, &mut w.qraw);
                vecmat(&w.xn, &lw.wk, d, nkv * dh, &mut w.kraw);
                vecmat(&w.xn, &lw.wv_hat, d, nkv * dh, &mut w.vr);
                for j in 0..nq {
                    apply_rope(&mut w.qraw[j * dh..(j + 1) * dh], w.pos, cfg.rope_theta);
                    self.proj.rotate_qk(
                        l,
                        j / g,
                        &w.qraw[j * dh..(j + 1) * dh],
                        &mut w.qhat[j * dh..(j + 1) * dh],
                    );
                }
                for hd in 0..nkv {
                    apply_rope(&mut w.kraw[hd * dh..(hd + 1) * dh], w.pos, cfg.rope_theta);
                    self.proj.rotate_qk(
                        l,
                        hd,
                        &w.kraw[hd * dh..(hd + 1) * dh],
                        &mut w.khat[hd * dh..(hd + 1) * dh],
                    );
                }
            });

            // 2. attention read phase: (sequence, kv-head) tasks, each with
            // exclusive access to one cache and its group's output slice
            {
                let mut tasks: Vec<AttnTask> = Vec::with_capacity(states.len() * nkv);
                for (st, w) in states.iter_mut().zip(works.iter_mut()) {
                    let caches = &mut st.caches[li * nkv..(li + 1) * nkv];
                    let head_outs = w.attn_out.chunks_mut(g * dh);
                    let head_qs = w.qhat.chunks(g * dh);
                    for (hd, ((cache, out_h), q_h)) in
                        caches.iter_mut().zip(head_outs).zip(head_qs).enumerate()
                    {
                        tasks.push(AttnTask {
                            cache: &mut **cache,
                            q: q_h,
                            k_cur: &w.khat[hd * dh..(hd + 1) * dh],
                            v_cur: &w.vr[hd * dh..(hd + 1) * dh],
                            out: out_h,
                        });
                    }
                }
                pool.for_each_mut(&mut tasks, |scratch, t| {
                    for (q, out) in t.q.chunks(dh).zip(t.out.chunks_mut(dh)) {
                        t.cache.attend_with(q, t.k_cur, t.v_cur, scratch, out);
                    }
                });
            }

            // 3. write phase: append the new rows, then output proj + MLP
            {
                let mut pairs: Vec<(&mut SequenceState, &mut DecodeWork)> =
                    states.iter_mut().zip(works.iter_mut()).collect();
                pool.for_each_mut(&mut pairs, |_scratch, pair| {
                    let (st, w) = pair;
                    for hd in 0..nkv {
                        st.caches[li * nkv + hd]
                            .append(&w.khat[hd * dh..(hd + 1) * dh], &w.vr[hd * dh..(hd + 1) * dh]);
                    }
                    vecmat(&w.attn_out, &lw.wo_hat, nq * dh, d, &mut w.proj);
                    for (hr, po) in w.h.iter_mut().zip(&w.proj) {
                        *hr += po;
                    }
                    rmsnorm(&w.h, &lw.mlp_norm, cfg.norm_eps, &mut w.xn);
                    vecmat(&w.xn, &lw.w1, d, cfg.d_ff, &mut w.mid);
                    w.mid.iter_mut().for_each(|m| *m = gelu(*m));
                    vecmat(&w.mid, &lw.w2, cfg.d_ff, d, &mut w.back);
                    for (hr, b) in w.h.iter_mut().zip(&w.back) {
                        *hr += b;
                    }
                });
            }
        }

        if emit_logits {
            pool.for_each_mut(&mut works, |_scratch, w| {
                rmsnorm(&w.h, &self.final_norm, cfg.norm_eps, &mut w.xn);
                vecmat(&w.xn, &self.lm_head, d, cfg.vocab, &mut w.logits);
            });
        }
        for st in states.iter_mut() {
            st.pos += 1;
        }
        if emit_logits {
            works.into_iter().map(|w| w.logits).collect()
        } else {
            works.into_iter().map(|w| w.h).collect()
        }
    }

    /// Build a randomly-initialised model — no artifacts needed.  Used by
    /// the throughput benches and the determinism tests; deterministic in
    /// `seed` (same stream as the original in-test tiny fixture).
    pub fn synthetic(cfg: ModelConfig, seed: u64) -> SwanModel {
        let (d, dh, nq, nkv) = (cfg.d_model, cfg.d_head, cfg.n_q_heads, cfg.n_kv_heads);
        let (dff, vocab, nl) = (cfg.d_ff, cfg.vocab, cfg.n_layers);
        let mut r = Pcg64::new(seed);
        let scale = 0.2f32;
        let mut layers = Vec::with_capacity(nl);
        for _ in 0..nl {
            let wv: Vec<f32> = r.normal_vec(d * nkv * dh).iter().map(|x| x * scale).collect();
            let wo: Vec<f32> = r.normal_vec(nq * dh * d).iter().map(|x| x * scale).collect();
            layers.push(LayerWeights {
                attn_norm: vec![1.0; d],
                wq: r.normal_vec(d * nq * dh).iter().map(|x| x * scale).collect(),
                wk: r.normal_vec(d * nkv * dh).iter().map(|x| x * scale).collect(),
                wv_hat: wv.clone(),
                wo_hat: wo.clone(),
                mlp_norm: vec![1.0; d],
                w1: r.normal_vec(d * dff).iter().map(|x| x * scale).collect(),
                w2: r.normal_vec(dff * d).iter().map(|x| x * scale).collect(),
                wv,
                wo,
            });
        }
        SwanModel {
            embed: r.normal_vec(vocab * d).iter().map(|x| x * 0.5).collect(),
            layers,
            final_norm: vec![1.0; d],
            lm_head: r.normal_vec(d * vocab).iter().map(|x| x * scale).collect(),
            proj: ProjectionSet::identity(nl, nkv, dh),
            cfg,
        }
    }
}

/// Per-sequence working buffers for one batched decode step (allocated
/// once per step; the attention score row lives in the per-worker scratch
/// instead).
struct DecodeWork {
    h: Vec<f32>,
    xn: Vec<f32>,
    qraw: Vec<f32>,
    kraw: Vec<f32>,
    vr: Vec<f32>,
    qhat: Vec<f32>,
    khat: Vec<f32>,
    attn_out: Vec<f32>,
    proj: Vec<f32>,
    mid: Vec<f32>,
    back: Vec<f32>,
    logits: Vec<f32>,
    pos: u32,
}

/// One `(sequence, kv-head)` attention task of the read phase: exclusive
/// `&mut` on that head's cache, shared reads on the query/current-token
/// rows, exclusive writes on the group's output slice.
struct AttnTask<'a> {
    cache: &'a mut dyn CachePolicy,
    /// The GQA group's query heads, `[g, d_h]` flat.
    q: &'a [f32],
    k_cur: &'a [f32],
    v_cur: &'a [f32],
    /// The group's output rows, `[g, d_h]` flat.
    out: &'a mut [f32],
}

/// Re-absorb Ŵ_V = W_V · P_VO and Ŵ_O = P_VO^T · W_O per head slice
/// (the rust mirror of `python/compile/calibrate.absorb_weights`).
fn absorb(cfg: &ModelConfig, lw: &mut LayerWeights, p_vo: &[Vec<f32>]) {
    let (d, dh, nq, nkv, g) = (cfg.d_model, cfg.d_head, cfg.n_q_heads, cfg.n_kv_heads, cfg.group());
    // wv [d, nkv*dh] -> per kv block column-transform
    for row in 0..d {
        for hd in 0..nkv {
            let block = lw.wv[row * nkv * dh + hd * dh..row * nkv * dh + (hd + 1) * dh].to_vec();
            let p = &p_vo[hd];
            let out = &mut lw.wv_hat[row * nkv * dh + hd * dh..row * nkv * dh + (hd + 1) * dh];
            for c in 0..dh {
                let mut s = 0.0f32;
                for r in 0..dh {
                    s += block[r] * p[r * dh + c];
                }
                out[c] = s;
            }
        }
    }
    // wo [nq*dh, d]: head slice j rows j*dh..(j+1)*dh -> P^T @ slice
    for j in 0..nq {
        let p = &p_vo[j / g];
        let src = lw.wo[j * dh * d..(j + 1) * dh * d].to_vec();
        let dst = &mut lw.wo_hat[j * dh * d..(j + 1) * dh * d];
        for r in 0..dh {
            for c in 0..d {
                let mut s = 0.0f32;
                for k in 0..dh {
                    // (P^T)[r,k] = P[k,r]
                    s += p[k * dh + r] * src[k * d + c];
                }
                dst[r * d + c] = s;
            }
        }
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::sparse::StorageMode;

    /// Build a tiny random model directly (no artifact needed).  Same
    /// RNG stream as before the [`SwanModel::synthetic`] refactor, so the
    /// weights (and every tolerance-checked expectation) are unchanged.
    pub(crate) fn tiny_model(nkv: usize) -> SwanModel {
        SwanModel::synthetic(
            ModelConfig {
                name: "tiny".into(),
                d_model: 32,
                n_layers: 2,
                n_q_heads: 4,
                n_kv_heads: nkv,
                d_head: 8,
                d_ff: 64,
                vocab: 96,
                rope_theta: 10000.0,
                norm_eps: 1e-5,
            },
            9,
        )
    }

    /// Dense decode after exact prefill == continuing the prefill: check
    /// that prefill(t..n) logits equal step-by-step decode logits with a
    /// dense policy.
    #[test]
    fn decode_consistent_with_prefill() {
        for nkv in [1usize, 4] {
            let m = tiny_model(nkv);
            let tokens: Vec<u32> = (0..10).map(|i| (i * 7 % 96) as u32).collect();
            let pf_full = m.prefill(&tokens);

            let pf_part = m.prefill(&tokens[..9]);
            let mut st = SequenceState::new(&m, PolicyKind::Dense);
            st.load_prefill(&pf_part);
            let logits = m.decode_step(&mut st, tokens[9]);
            for (a, b) in logits.iter().zip(&pf_full.logits) {
                assert!((a - b).abs() < 1e-3, "nkv={nkv}: {a} vs {b}");
            }
            assert_eq!(st.pos, 10);
        }
    }

    /// SWAN at full retention with a roomy buffer must equal dense.
    #[test]
    fn swan_full_retention_matches_dense_decode() {
        let m = tiny_model(2);
        let tokens: Vec<u32> = (0..8).map(|i| (i * 5 % 96) as u32).collect();
        let pf = m.prefill(&tokens);

        let mut dense = SequenceState::new(&m, PolicyKind::Dense);
        dense.load_prefill(&pf);
        let mut swan = SequenceState::new(
            &m,
            PolicyKind::Swan { k_active: 8, buffer: 4, mode: StorageMode::F32 },
        );
        swan.load_prefill(&pf);

        let mut t = 3u32;
        for _ in 0..4 {
            let a = m.decode_step(&mut dense, t);
            let b = m.decode_step(&mut swan, t);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-3, "{x} vs {y}");
            }
            t = crate::tensor::ops::argmax(&a) as u32;
        }
    }

    /// Projection ablation with orthogonal P must leave the *unpruned*
    /// model unchanged (Lemma A.1/A.2 in the rust path).
    #[test]
    fn random_projection_lossless_without_pruning() {
        let mut m = tiny_model(2);
        let tokens: Vec<u32> = (0..8).map(|i| (i * 3 % 96) as u32).collect();
        let base = m.prefill(&tokens).logits;

        // apply a random orthogonal projection set + re-absorb
        let proj = ProjectionSet::random(2, 2, 8, 42);
        for (l, lw) in m.layers.iter_mut().enumerate() {
            absorb(&m.cfg, lw, &proj.p_vo[l]);
        }
        m.proj = proj;
        let rotated = m.prefill(&tokens).logits;
        for (a, b) in base.iter().zip(&rotated) {
            assert!((a - b).abs() < 1e-2, "{a} vs {b}");
        }
    }

    /// Splitting the layer range across two "stages" (embed+layer 0, then
    /// layer 1+logits) must be bit-identical to the full-range call, for
    /// both prefill and decode — the contract the pipeline fleet rests on.
    #[test]
    fn layer_range_split_is_bit_identical_to_full_run() {
        let m = tiny_model(2);
        let tokens: Vec<u32> = (0..9).map(|i| (i * 13 % 96) as u32).collect();
        let mut pool = WorkerPool::serial();

        // full-model reference
        let pf_full = m.prefill(&tokens);
        let kind = PolicyKind::Swan { k_active: 4, buffer: 2, mode: StorageMode::F16 };
        let mut st_full = SequenceState::new(&m, kind);
        st_full.load_prefill(&pf_full);
        let mut tok = crate::tensor::ops::argmax(&pf_full.logits) as u32;
        let mut full_stream = vec![tok];
        for _ in 0..6 {
            let logits = m.decode_step(&mut st_full, tok);
            tok = crate::tensor::ops::argmax(&logits) as u32;
            full_stream.push(tok);
        }

        // two-stage split: prefill
        let mut h = m.embed_prompt(&tokens);
        let pf0 = m.prefill_layers(&mut h, 0..1, &mut pool);
        let pf1 = m.prefill_layers(&mut h, 1..2, &mut pool);
        let logits = m.prefill_logits(&h);
        assert_eq!(logits, pf_full.logits, "stage-split prefill logits diverged");
        assert_eq!(pf0.khat[0], pf_full.khat[0]);
        assert_eq!(pf1.khat[0], pf_full.khat[1]);

        let mut st0 = SequenceState::for_layers(&m, kind, 1);
        let mut st1 = SequenceState::for_layers(&m, kind, 1);
        st0.load_prefill(&pf0);
        st1.load_prefill(&pf1);

        // two-stage split: decode
        let mut tok = crate::tensor::ops::argmax(&logits) as u32;
        let mut split_stream = vec![tok];
        for _ in 0..6 {
            let h = m.decode_step_pipeline(
                std::slice::from_mut(&mut st0),
                StageInput::Tokens(&[tok]),
                0..1,
                false,
                &mut pool,
            );
            let logits = m
                .decode_step_pipeline(
                    std::slice::from_mut(&mut st1),
                    StageInput::Hidden(h),
                    1..2,
                    true,
                    &mut pool,
                )
                .pop()
                .unwrap();
            tok = crate::tensor::ops::argmax(&logits) as u32;
            split_stream.push(tok);
        }
        assert_eq!(full_stream, split_stream, "pipeline split diverged from full run");
        assert_eq!(st0.pos, st_full.pos);
        assert_eq!(st1.pos, st_full.pos);
    }

    #[test]
    fn storage_accounting_spans_all_caches() {
        let m = tiny_model(2);
        let mut st = SequenceState::new(
            &m,
            PolicyKind::Swan { k_active: 4, buffer: 2, mode: StorageMode::F16 },
        );
        let pf = m.prefill(&[1, 2, 3, 4, 5, 6]);
        st.load_prefill(&pf);
        // 2 layers * 2 kv heads, each: 4 sparse tokens (2*(3*4+2) bytes) + 2 buffered
        let per_cache = 4 * 2 * (3 * 4 + 2) + 2 * 2 * 8 * 2;
        assert_eq!(st.storage_bytes(), 4 * per_cache);
    }
}
