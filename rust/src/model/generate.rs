//! Token generation over a [`SequenceState`]: greedy and temperature
//! sampling, plus a convenience driver used by the eval harness and
//! examples.

use crate::model::transformer::{SequenceState, SwanModel};
use crate::tensor::ops::{argmax, softmax_inplace};
use crate::util::Pcg64;

/// Decoding strategy.
#[derive(Clone, Copy, Debug)]
pub enum Sampling {
    Greedy,
    /// Softmax sampling at the given temperature.
    Temperature(f32),
}

/// Generate up to `max_new` tokens after `first_token`; stops early if
/// `stop` returns true for a produced token.
pub fn generate<F: FnMut(u32) -> bool>(
    model: &SwanModel,
    state: &mut SequenceState,
    first_token: u32,
    max_new: usize,
    sampling: Sampling,
    rng: &mut Pcg64,
    mut stop: F,
) -> Vec<u32> {
    let mut out = Vec::with_capacity(max_new);
    let mut tok = first_token;
    for _ in 0..max_new {
        let logits = model.decode_step(state, tok);
        let next = match sampling {
            Sampling::Greedy => argmax(&logits) as u32,
            Sampling::Temperature(t) => {
                let mut probs: Vec<f32> = logits.iter().map(|l| l / t.max(1e-4)).collect();
                softmax_inplace(&mut probs);
                let mut u = rng.next_f32();
                let mut pick = probs.len() - 1;
                for (i, p) in probs.iter().enumerate() {
                    if u < *p {
                        pick = i;
                        break;
                    }
                    u -= *p;
                }
                pick as u32
            }
        };
        out.push(next);
        if stop(next) {
            break;
        }
        tok = next;
    }
    out
}

/// Greedy continuation helper.
pub fn greedy(model: &SwanModel, state: &mut SequenceState, first_token: u32, max_new: usize) -> Vec<u32> {
    let mut rng = Pcg64::new(0);
    generate(model, state, first_token, max_new, Sampling::Greedy, &mut rng, |_| false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::PolicyKind;
    use crate::model::transformer::tests::tiny_model;

    #[test]
    fn greedy_is_deterministic() {
        let m = tiny_model(2);
        let run = || {
            let mut st = crate::model::SequenceState::new(&m, PolicyKind::Dense);
            let pf = m.prefill(&[1, 2, 3]);
            st.load_prefill(&pf);
            greedy(&m, &mut st, 4, 8)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn stop_predicate_halts() {
        let m = tiny_model(2);
        let mut st = crate::model::SequenceState::new(&m, PolicyKind::Dense);
        let pf = m.prefill(&[1, 2, 3]);
        st.load_prefill(&pf);
        let mut rng = Pcg64::new(1);
        let toks = generate(&m, &mut st, 4, 50, Sampling::Greedy, &mut rng, |_| true);
        assert_eq!(toks.len(), 1);
    }

    #[test]
    fn temperature_sampling_varies() {
        let m = tiny_model(2);
        let mut outs = std::collections::HashSet::new();
        for seed in 0..5 {
            let mut st = crate::model::SequenceState::new(&m, PolicyKind::Dense);
            let pf = m.prefill(&[1, 2, 3]);
            st.load_prefill(&pf);
            let mut rng = Pcg64::new(seed);
            outs.insert(generate(&m, &mut st, 4, 6, Sampling::Temperature(2.0), &mut rng, |_| false));
        }
        assert!(outs.len() > 1, "temperature sampling produced identical streams");
    }
}
