//! Minimal argument parser (clap is unavailable offline).
//!
//! Grammar: `swan <command> [positional...] [--flag [value]]...`.

use std::collections::HashMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: HashMap<String, Option<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut it = args.into_iter().peekable();
        let command = it.next().unwrap_or_default();
        let mut out = Args { command, ..Default::default() };
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let (name, inline) = match name.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (name.to_string(), None),
                };
                let value = if inline.is_some() {
                    inline
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    it.next()
                } else {
                    None
                };
                out.flags.insert(name, value);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.as_deref())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected a number, got '{v}'")),
        }
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_f32(&self, name: &str, default: f32) -> anyhow::Result<f32> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name}: expected a float, got '{v}'")),
        }
    }

    /// Parse an optional flag: `None` when absent, an error when present
    /// but unparsable (for per-request overrides like `--seed`/`--k`).
    pub fn get_opt_u64(&self, name: &str) -> anyhow::Result<Option<u64>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{name}: expected a number, got '{v}'")),
        }
    }
}

pub const USAGE: &str = "\
swan — Sparse Winnowed Attention serving stack

USAGE:
  swan serve    [--model M] [--bind ADDR] [--k-active K] [--buffer B]
                [--mode 16|8] [--max-batch N] [--mem-budget BYTES] [--dense]
                [--shards N]           engine shards behind the router (default 1)
                [--pipeline P]         layer-shard the model: group the shards
                                       into N/P pipeline groups of P stages,
                                       each stage owning a contiguous layer
                                       range (default 1 = whole-model shards;
                                       N must be a multiple of P)
                [--balance P]          placement: round-robin|least-queued|mem-aware
                [--decode-workers N]   decode threads per shard (0 = serial)
                [--admit-lookahead W]  admission scans the first W queued
                                       requests under memory pressure (default 4)
                [--pool]               paged KV block pool: block-accounted
                                       admission + block-granular preemption
                                       (native pipeline path; output identical)
                [--block-tokens N]     rows per pool block (default 16)
                [--prefix-cache]       cross-request KV prefix reuse: cache
                                       retired prompts' full-block prefixes
                                       and attach them copy-on-write to later
                                       prompts sharing the prefix (pipeline
                                       path; implies --pool; SET prefix
                                       on|off toggles it live)
                [--drain-timeout MS]   how long a draining shard (DRAIN /
                                       SET shards scale-down) waits for
                                       in-flight work before migrating it
                                       to healthy shards (default 5000)
                [--kernels K]          compute kernels: auto|scalar|avx2
                                       (accepted by every command; default auto)
  swan generate <prompt...> [--model M] [--max-new N] [--k-active K]
                [--mode 16|8] [--dense]
                [--temperature T]      softmax temperature (0 = greedy)
                [--top-p P]            nucleus sampling mass (1 = off)
                [--rep-penalty R]      repetition penalty (1 = off)
                [--seed S]             RNG stream seed override
                [--k K]                per-request compression override
                                       (this request only; --k-active sets
                                       the engine-wide level)
                [--stream]             print tokens as they decode
  swan eval     [--model M] [--cases N]       run the task battery natively
  swan repro    <fig2a|fig2b|fig3|fig4|fig5|fig6|table1|table2|table3|
                 breakeven|motivation|all> [--cases N]
  swan breakeven [--d-head D] [--buffer B]    Eq.2 break-even calculator
  swan info                                   artifact + runtime summary

Artifacts are found via $SWAN_ARTIFACTS or ./artifacts (run `make
artifacts` first).";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_command_and_positionals() {
        let a = parse("generate hello world --max-new 8");
        assert_eq!(a.command, "generate");
        assert_eq!(a.positional, vec!["hello", "world"]);
        assert_eq!(a.get_usize("max-new", 1).unwrap(), 8);
    }

    #[test]
    fn parses_flags_with_and_without_values() {
        let a = parse("serve --dense --k-active 16 --bind=0.0.0.0:1234");
        assert!(a.has("dense"));
        assert_eq!(a.get("k-active"), Some("16"));
        assert_eq!(a.get("bind"), Some("0.0.0.0:1234"));
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse("serve --k-active nope");
        assert!(a.get_usize("k-active", 1).is_err());
    }

    #[test]
    fn float_and_optional_flags() {
        let a = parse("generate hi --temperature 0.8 --seed 42");
        assert_eq!(a.get_f32("temperature", 0.0).unwrap(), 0.8);
        assert_eq!(a.get_f32("top-p", 1.0).unwrap(), 1.0);
        assert_eq!(a.get_opt_u64("seed").unwrap(), Some(42));
        assert_eq!(a.get_opt_u64("k").unwrap(), None);
        assert!(parse("generate hi --top-p x").get_f32("top-p", 1.0).is_err());
        assert!(parse("generate hi --seed x").get_opt_u64("seed").is_err());
    }

    #[test]
    fn missing_flag_uses_default() {
        let a = parse("serve");
        assert_eq!(a.get_usize("k-active", 32).unwrap(), 32);
        assert_eq!(a.get_str("model", "m"), "m");
    }
}
