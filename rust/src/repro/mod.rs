//! One module per paper table/figure (see DESIGN.md per-experiment index).
//!
//! Every module exposes `run(&mut ReproCtx) -> anyhow::Result<String>`
//! printing the same rows/series the paper reports, measured on the
//! swan-nano artifacts.  `swan repro <name|all>` drives them; outputs are
//! also written to `results/<name>.txt` for EXPERIMENTS.md.

pub mod breakeven;
pub mod fig2a;
pub mod fig2b;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod motivation;
pub mod table1;
pub mod table2;
pub mod table3;

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::Context;

use crate::model::{SwanModel, WeightFile};
use crate::swan::projection::ProjectionVariant;

/// Shared context: lazily-loaded models + output directory.
pub struct ReproCtx {
    pub artifacts: PathBuf,
    pub results_dir: PathBuf,
    /// Scale factor for case counts (1 = paper-repro default; smaller for
    /// smoke runs).
    pub cases: usize,
    models: HashMap<String, SwanModel>,
    weight_files: HashMap<String, WeightFile>,
}

impl ReproCtx {
    pub fn new(artifacts: PathBuf, cases: usize) -> ReproCtx {
        let results_dir = artifacts.parent().unwrap_or(&artifacts).join("results");
        ReproCtx {
            artifacts,
            results_dir,
            cases,
            models: HashMap::new(),
            weight_files: HashMap::new(),
        }
    }

    pub fn weight_file(&mut self, name: &str) -> anyhow::Result<&WeightFile> {
        if !self.weight_files.contains_key(name) {
            let wf = WeightFile::load(&self.artifacts.join(format!("weights_{name}.bin")))
                .with_context(|| format!("weights for {name} (run `make artifacts`)"))?;
            self.weight_files.insert(name.to_string(), wf);
        }
        Ok(&self.weight_files[name])
    }

    pub fn model(&mut self, name: &str) -> anyhow::Result<&SwanModel> {
        if !self.models.contains_key(name) {
            let wf = WeightFile::load(&self.artifacts.join(format!("weights_{name}.bin")))
                .with_context(|| format!("weights for {name} (run `make artifacts`)"))?;
            let m = SwanModel::load(&wf, ProjectionVariant::Calibrated, 0)?;
            self.models.insert(name.to_string(), m);
        }
        Ok(&self.models[name])
    }

    /// Load a model with an ablated projection set (Table 3).
    pub fn model_with_variant(
        &mut self,
        name: &str,
        variant: ProjectionVariant,
        seed: u64,
    ) -> anyhow::Result<SwanModel> {
        let wf = self.weight_file(name)?;
        SwanModel::load(wf, variant, seed)
    }

    /// Persist an experiment's output and return it.
    pub fn emit(&self, exp: &str, body: String) -> anyhow::Result<String> {
        std::fs::create_dir_all(&self.results_dir).ok();
        std::fs::write(self.results_dir.join(format!("{exp}.txt")), &body)
            .with_context(|| format!("writing results/{exp}.txt"))?;
        Ok(body)
    }
}

/// All experiment names, in paper order.
pub const ALL: &[&str] = &[
    "motivation", "fig2a", "fig2b", "fig3", "fig4", "fig5", "fig6",
    "table1", "table2", "table3", "breakeven",
];

/// Dispatch by name.
pub fn run(name: &str, ctx: &mut ReproCtx) -> anyhow::Result<String> {
    match name {
        "motivation" => motivation::run(ctx),
        "fig2a" => fig2a::run(ctx),
        "fig2b" => fig2b::run(ctx),
        "fig3" => fig3::run(ctx),
        "fig4" => fig4::run(ctx),
        "fig5" => fig5::run(ctx),
        "fig6" => fig6::run(ctx),
        "table1" => table1::run(ctx),
        "table2" => table2::run(ctx),
        "table3" => table3::run(ctx),
        "breakeven" => breakeven::run(ctx),
        other => anyhow::bail!("unknown experiment '{other}' (available: {ALL:?})"),
    }
}
