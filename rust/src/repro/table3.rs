//! Table 3: projection-specificity ablation at 50% retention.
//!
//! Variants: ours (calibrated), head-shuffle, layer-shuffle, KV-shuffle,
//! random orthogonal (+ identity as an extra floor).  Paper finding: the
//! calibrated, component-specific projections win on every benchmark;
//! random is worst; all shuffles cost accuracy — the learned subspaces
//! are layer-, head- and component-specific.

use crate::eval::tasks::standard_battery;
use crate::eval::Harness;
use crate::kvcache::PolicyKind;
use crate::repro::ReproCtx;
use crate::sparse::StorageMode;
use crate::swan::projection::ProjectionVariant;
use crate::util::Pcg64;

pub fn run(ctx: &mut ReproCtx) -> anyhow::Result<String> {
    let n_cases = ctx.cases.max(6);
    let d_h = 64usize;
    let k = d_h / 2; // 50% retention, the paper's ablation point
    let tasks = standard_battery(n_cases, 31);
    let text = crate::eval::corpus::mixed_text(&mut Pcg64::new(77), 280);

    let mut out = String::from("# Table 3 — projection ablation (50% retention, bt=0)\n\n");
    out.push_str(&format!(
        "{:<26} {:>8} {:>8} {:>8} {:>8} {:>8} {:>9}\n",
        "projection", "arith", "fact", "passkey", "code", "ppl", "avg-acc"
    ));
    let mut ours_avg = -1.0f64;
    for variant in ProjectionVariant::ALL {
        let model = ctx.model_with_variant("swan-nano-gqa", variant, 1234)?;
        let mut h = Harness::new(&model);
        let policy = PolicyKind::Swan { k_active: k, buffer: 0, mode: StorageMode::F16 };
        let mut acc = Vec::new();
        for t in &tasks {
            acc.push(h.run_task(t, policy).accuracy);
        }
        let ppl = h.perplexity(&text, policy);
        let avg = acc.iter().sum::<f64>() / acc.len() as f64;
        if variant == ProjectionVariant::Calibrated {
            ours_avg = avg;
        }
        out.push_str(&format!(
            "{:<26} {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>8.2} {:>9.3}\n",
            variant.label(), acc[0], acc[1], acc[2], acc[3], ppl, avg
        ));
    }
    out.push_str(&format!(
        "\nours avg: {ours_avg:.3} — paper: calibrated projections beat every\n\
         shuffle; random projection degrades most.\n"
    ));
    ctx.emit("table3", out)
}
