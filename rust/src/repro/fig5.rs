//! Figure 5: Winogrande/HellaSwag (continuation choice), TruthfulQA
//! (stability under compression), WikiText perplexity — on both models.
//!
//! Paper findings to reproduce: continuation tasks are resilient until a
//! sharp threshold; perplexity holds to ~40% then spikes; the spike on the
//! MHA model is far smaller than on the GQA model (the "3x less severe"
//! claim).

use crate::eval::Harness;
use crate::kvcache::PolicyKind;
use crate::repro::ReproCtx;
use crate::sparse::StorageMode;
use crate::util::Pcg64;

pub fn run(ctx: &mut ReproCtx) -> anyhow::Result<String> {
    let n_cases = ctx.cases.max(6);
    let mut out = String::from(
        "# Fig 5 — continuation choice + perplexity, GQA vs MHA\n\n");
    let d_h = 64usize;
    let ratios = [0.75f64, 0.5, 0.3, 0.15, 0.08, 0.04];
    let mut spikes = Vec::new();
    for model_name in ["swan-nano-gqa", "swan-nano-mha"] {
        let model = ctx.model(model_name)?;
        let mut h = Harness::new(model);
        let text = crate::eval::corpus::mixed_text(&mut Pcg64::new(1234), 360);

        out.push_str(&format!("## {model_name}\n"));
        out.push_str(&format!(
            "{:<34} {:>12} {:>12}\n", "policy", "cont-choice", "perplexity"));
        let dense_c = h.continuation_choice(PolicyKind::Dense, n_cases, 200, 16, 5);
        let dense_p = h.perplexity(&text, PolicyKind::Dense);
        out.push_str(&format!(
            "{:<34} {:>12.3} {:>12.3}\n", "dense", dense_c, dense_p));
        let mut worst_ppl: f64 = dense_p;
        for &r in &ratios {
            let k = ((r * d_h as f64).round() as usize).max(1);
            for (mode, bt) in [(StorageMode::F16, 64usize), (StorageMode::F16, 0)] {
                let policy = PolicyKind::Swan { k_active: k, buffer: bt, mode };
                let c = h.continuation_choice(policy, n_cases, 200, 16, 5);
                let p = h.perplexity(&text, policy);
                if bt == 0 {
                    worst_ppl = worst_ppl.max(p);
                }
                out.push_str(&format!("{:<34} {:>12.3} {:>12.3}\n", policy.label(), c, p));
            }
        }
        spikes.push((model_name, worst_ppl / dense_p));
        out.push('\n');
    }
    out.push_str("perplexity spike (worst bt=0 / dense):\n");
    for (name, s) in &spikes {
        out.push_str(&format!("  {name}: {s:.2}x\n"));
    }
    if spikes.len() == 2 {
        out.push_str(&format!(
            "GQA/MHA spike ratio: {:.2} (paper: MHA ~3x less severe)\n",
            spikes[0].1 / spikes[1].1
        ));
    }
    ctx.emit("fig5", out)
}
