//! Figure 4: long-context suite (LongBench analogue), buffered vs
//! zero-buffer.
//!
//! Paper findings to reproduce: bt=0 collapses completely on long
//! contexts; bt=128 degrades gracefully and stays competitive at 50-60%
//! savings; the 8-bit variant is strong on the summarisation-style tasks
//! at high compression.

use crate::eval::tasks::long_battery;
use crate::eval::{harness::format_table, Harness};
use crate::kvcache::PolicyKind;
use crate::repro::ReproCtx;
use crate::sparse::StorageMode;

pub fn run(ctx: &mut ReproCtx) -> anyhow::Result<String> {
    let n_cases = ctx.cases.max(5);
    let model = ctx.model("swan-nano-gqa")?;
    let mut h = Harness::new(model);
    let d_h = model.cfg.d_head;
    let tasks = long_battery(n_cases, 91);

    let mut rows = Vec::new();
    for t in &tasks {
        rows.push(h.run_task(t, PolicyKind::Dense));
    }
    for &r in &[0.5f64, 0.25, 0.1, 0.05] {
        let k = ((r * d_h as f64).round() as usize).max(1);
        for (mode, bt) in [
            (StorageMode::F16, 128usize),
            (StorageMode::F8, 128),
            (StorageMode::F16, 0),
            (StorageMode::F8, 0),
        ] {
            for t in &tasks {
                rows.push(h.run_task(t, PolicyKind::Swan { k_active: k, buffer: bt, mode }));
            }
        }
    }
    let mut out = String::from("# Fig 4 — long-context suite (LongBench analogue)\n\n");
    out.push_str(&format_table("swan-nano-gqa long-context", &rows));

    // averages per (bt, mode) over the compressed grid
    out.push_str("\naverages over tasks and ratios:\n");
    let groups = ["16-bit bt=128", "8-bit bt=128", "16-bit bt=0", "8-bit bt=0"];
    for g in groups {
        let (mode_lbl, bt_lbl) = g.split_once(" bt=").unwrap();
        let sel: Vec<f64> = rows
            .iter()
            .filter(|r| {
                r.policy.contains(&format!("swan-{mode_lbl}"))
                    && r.policy.ends_with(&format!("bt={bt_lbl}"))
            })
            .map(|r| r.accuracy)
            .collect();
        if !sel.is_empty() {
            out.push_str(&format!(
                "  {g:<16} avg accuracy {:.3}\n",
                sel.iter().sum::<f64>() / sel.len() as f64
            ));
        }
    }
    out.push_str("\npaper shape: bt=0 complete collapse; bt=128 graceful degradation;\n\
                  8-bit buffered strong at aggressive compression.\n");
    ctx.emit("fig4", out)
}
