//! Table 2: asymmetric key/value retention ablation (b = 0).
//!
//! TopK_R + TopV_R = 1.0; paper finding: both components matter, extreme
//! asymmetry is catastrophic either way, and the balanced 0.5/0.5 point
//! is best or near-best everywhere (0.6/0.4 close behind).

use crate::eval::tasks::standard_battery;
use crate::eval::Harness;
use crate::kvcache::PolicyKind;
use crate::repro::ReproCtx;
use crate::sparse::StorageMode;
use crate::util::Pcg64;

pub fn run(ctx: &mut ReproCtx) -> anyhow::Result<String> {
    let n_cases = ctx.cases.max(6);
    let model = ctx.model("swan-nano-gqa")?;
    let mut h = Harness::new(model);
    let d_h = model.cfg.d_head;
    let tasks = standard_battery(n_cases, 21);
    let text = crate::eval::corpus::mixed_text(&mut Pcg64::new(55), 280);

    let mut out = String::from("# Table 2 — key/value retention split (b=0, 16-bit)\n\n");
    out.push_str(&format!(
        "{:<8} {:<8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>9}\n",
        "TopK_R", "TopV_R", "arith", "fact", "passkey", "code", "ppl", "avg-acc"
    ));
    let mut best: (f64, f64, f64) = (0.0, 0.0, -1.0);
    for i in 1..=9usize {
        let kr = i as f64 / 10.0;
        let vr = 1.0 - kr;
        let k_keys = ((kr * d_h as f64).round() as usize).max(1);
        let k_vals = ((vr * d_h as f64).round() as usize).max(1);
        let policy = PolicyKind::SwanAsym {
            k_keys,
            k_vals,
            buffer: 0,
            mode: StorageMode::F16,
        };
        let mut acc = Vec::new();
        for t in &tasks {
            acc.push(h.run_task(t, policy).accuracy);
        }
        let ppl = h.perplexity(&text, policy);
        let avg = acc.iter().sum::<f64>() / acc.len() as f64;
        if avg > best.2 {
            best = (kr, vr, avg);
        }
        out.push_str(&format!(
            "{kr:<8.1} {vr:<8.1} {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>8.2} {:>9.3}\n",
            acc[0], acc[1], acc[2], acc[3], ppl, avg
        ));
    }
    out.push_str(&format!(
        "\nbest split: TopK_R={:.1}/TopV_R={:.1} (paper: 0.5/0.5 best or near-best,\n\
         extremes catastrophic on both sides)\n",
        best.0, best.1
    ));
    ctx.emit("table2", out)
}
