//! Table 1: performance as a function of the retention ratio
//! (k_active / d_h) with the serving buffer, on the GQA model.
//!
//! Paper rows: ratio ∈ {1.0 (baseline), 0.9, 0.75, 0.5, 0.3}; performance
//! stays within ~1% of baseline at 0.75, degrades <5% at 0.5, and
//! collapses at 0.3 (GSM8K first).

use crate::eval::tasks::standard_battery;
use crate::eval::Harness;
use crate::kvcache::PolicyKind;
use crate::repro::fig2b::fewshot_arith_cases;
use crate::repro::ReproCtx;
use crate::sparse::StorageMode;
use crate::util::Pcg64;

pub fn run(ctx: &mut ReproCtx) -> anyhow::Result<String> {
    let n_cases = ctx.cases.max(6);
    let model = ctx.model("swan-nano-gqa")?;
    let mut h = Harness::new(model);
    let d_h = model.cfg.d_head;
    let tasks = standard_battery(n_cases, 11);
    let arith_fs = fewshot_arith_cases(n_cases, 5, 12);
    let text = crate::eval::corpus::mixed_text(&mut Pcg64::new(99), 320);

    let mut out = String::from("# Table 1 — performance vs retention ratio (bt=64, 16-bit)\n\n");
    out.push_str(&format!(
        "{:<8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>9}\n",
        "ratio", "arith", "fact", "passkey", "code", "gsm-fs", "ppl", "avg-acc"
    ));
    for &r in &[1.0f64, 0.75, 0.5, 0.3, 0.12, 0.05] {
        let policy = if r >= 1.0 {
            PolicyKind::Dense
        } else {
            let k = ((r * d_h as f64).round() as usize).max(1);
            PolicyKind::Swan { k_active: k, buffer: 64, mode: StorageMode::F16 }
        };
        let mut acc = Vec::new();
        for t in &tasks {
            acc.push(h.run_task(t, policy).accuracy);
        }
        let gsm = h.run_cases("gsm-fs", &arith_fs, policy).accuracy;
        let ppl = h.perplexity(&text, policy);
        let avg = (acc.iter().sum::<f64>() + gsm) / (acc.len() + 1) as f64;
        out.push_str(&format!(
            "{:<8} {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>8.2} {:>9.3}\n",
            if r >= 1.0 { "1.0 (B)".to_string() } else { format!("{r}") },
            acc[0], acc[1], acc[2], acc[3], gsm, ppl, avg
        ));
    }
    out.push_str("\npaper shape: ~flat to 0.75, mild drop at 0.5, collapse at 0.3\n\
                  (reasoning task most sensitive; perplexity spikes at 0.3).\n");
    ctx.emit("table1", out)
}
