//! Figure 3: standard NLP battery on both architectures (GQA vs MHA).
//!
//! Paper findings to reproduce: the bt=64 buffer keeps accuracy high to
//! 50-60% savings while bt=0 degrades sharply; the 8-bit variant shines
//! under high compression on knowledge tasks; the MHA model (OLMoE
//! analogue) degrades *less* than the GQA model (Llama analogue).

use crate::eval::tasks::standard_battery;
use crate::eval::{harness::format_table, Harness};
use crate::kvcache::PolicyKind;
use crate::repro::ReproCtx;
use crate::sparse::StorageMode;

pub fn run(ctx: &mut ReproCtx) -> anyhow::Result<String> {
    let n_cases = ctx.cases.max(6);
    let mut out = String::from("# Fig 3 — standard NLP battery, GQA vs MHA\n\n");
    let d_h = 64usize;
    let ratios = [0.5f64, 0.2, 0.08];
    for model_name in ["swan-nano-gqa", "swan-nano-mha"] {
        let model = ctx.model(model_name)?;
        let mut h = Harness::new(model);
        let tasks = standard_battery(n_cases, 77);
        let mut rows = Vec::new();
        for t in &tasks {
            rows.push(h.run_task(t, PolicyKind::Dense));
        }
        for &r in &ratios {
            let k = ((r * d_h as f64).round() as usize).max(1);
            for (mode, bt) in [
                (StorageMode::F16, 64usize),
                (StorageMode::F8, 64),
                (StorageMode::F16, 0),
            ] {
                for t in &tasks {
                    rows.push(h.run_task(t, PolicyKind::Swan { k_active: k, buffer: bt, mode }));
                }
            }
        }
        out.push_str(&format_table(model_name, &rows));
        // per-model average degradation vs dense (the MHA-vs-GQA claim)
        let dense_avg: f64 =
            rows[..tasks.len()].iter().map(|r| r.accuracy).sum::<f64>() / tasks.len() as f64;
        let comp_avg: f64 = rows[tasks.len()..]
            .iter()
            .map(|r| r.accuracy)
            .sum::<f64>()
            / (rows.len() - tasks.len()) as f64;
        out.push_str(&format!(
            "{model_name}: dense avg {dense_avg:.3}, compressed avg {comp_avg:.3}, \
             drop {:.3}\n\n",
            dense_avg - comp_avg
        ));
    }
    out.push_str("paper shape: buffered variants stay near dense; bt=0 collapses;\n\
                  the MHA model's drop is consistently smaller than the GQA model's.\n");
    ctx.emit("fig3", out)
}
