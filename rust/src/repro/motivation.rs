//! §1 motivation: KV-cache memory vs model weights.
//!
//! Paper claim: Llama-2 7B at 32k context, batch 16 needs ~14 GB of
//! weights but ~256 GB of KV cache.  We reproduce the arithmetic and show
//! what SWAN saves at the paper's operating points.

use crate::repro::ReproCtx;
use crate::sparse::memory::{human_bytes, MemoryModel, StorageMode};

pub fn run(ctx: &mut ReproCtx) -> anyhow::Result<String> {
    let m = MemoryModel::llama2_7b();
    let mut out = String::from("# §1 motivation — KV-cache memory model (Llama-2 7B)\n\n");
    out.push_str(&format!(
        "{:<10} {:<7} {:>12} {:>14} {:>14} {:>14}\n",
        "seq_len", "batch", "dense", "swan k=64/16b", "swan k=64/8b", "swan k=32/8b"
    ));
    for &(seq, batch) in &[(4096usize, 1usize), (32 * 1024, 1), (32 * 1024, 16), (128 * 1024, 16)] {
        let dense = m.dense_bytes(seq, batch);
        let s16 = m.swan_bytes(seq, 128, 64, StorageMode::F16) * batch;
        let s8 = m.swan_bytes(seq, 128, 64, StorageMode::F8) * batch;
        let s8a = m.swan_bytes(seq, 128, 32, StorageMode::F8) * batch;
        out.push_str(&format!(
            "{:<10} {:<7} {:>12} {:>14} {:>14} {:>14}\n",
            seq, batch,
            human_bytes(dense),
            human_bytes(s16),
            human_bytes(s8),
            human_bytes(s8a),
        ));
    }
    let dense_32k16 = m.dense_bytes(32 * 1024, 16) as f64 / (1u64 << 30) as f64;
    out.push_str(&format!(
        "\npaper: ~256 GB at 32k/batch-16 -> measured model {dense_32k16:.0} GiB\n"
    ));
    out.push_str(&format!(
        "memory saving at k=64 (50% retention), 16-bit, 32k ctx: {:.1}%\n",
        100.0 * (1.0 - m.swan_ratio(32 * 1024, 128, 64, StorageMode::F16))
    ));
    out.push_str(&format!(
        "memory saving at k=64, 8-bit: {:.1}% (paper band: 50-60%)\n",
        100.0 * (1.0 - m.swan_ratio(32 * 1024, 128, 64, StorageMode::F8))
    ));
    ctx.emit("motivation", out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_number_within_band() {
        let mut ctx = ReproCtx::new(std::env::temp_dir(), 1);
        ctx.results_dir = std::env::temp_dir().join("swan-results-test");
        let out = run(&mut ctx).unwrap();
        assert!(out.contains("256 GiB") || out.contains("255 GiB") || out.contains("257 GiB"),
                "{out}");
    }
}
