//! Eq. 2 / Appendix A.2.1: the computational break-even point.
//!
//! Three views: (1) the closed form; (2) exact FLOP counting; (3) measured
//! wallclock of the rust decompression-free attention vs dense attention
//! over a sequence-length sweep (the hardware analogue — the crossover L
//! should fall near the formula's prediction, scaled by implementation
//! constants).

use crate::repro::ReproCtx;
use crate::sparse::StorageMode;
use crate::swan::breakeven::{breakeven_by_counting, breakeven_length, flops_std, flops_swan};
use crate::swan::hybrid_cache::{HybridCache, SwanParams};
use crate::util::stats::bench_batched;
use crate::util::Pcg64;

pub fn run(ctx: &mut ReproCtx) -> anyhow::Result<String> {
    let mut out = String::from("# Eq. 2 — computational break-even (d_h = 128)\n\n");
    out.push_str("## closed form vs FLOP counting (Appendix A.2.1 examples)\n");
    out.push_str(&format!(
        "{:<8} {:<10} {:>14} {:>12} {:>10}\n",
        "buffer", "k_active", "formula L*", "counted L*", "paper"
    ));
    let paper: &[(usize, usize, usize)] =
        &[(0, 32, 171), (0, 64, 256), (0, 96, 512), (128, 32, 299), (128, 64, 384), (128, 96, 640)];
    for &(b, k, expect) in paper {
        let f = breakeven_length(128, b, k).unwrap();
        let c = breakeven_by_counting(128, b, k, 100_000).unwrap();
        out.push_str(&format!(
            "{b:<8} {k:<10} {f:>14.1} {c:>12} {expect:>10}\n"
        ));
    }

    out.push_str("\n## FLOP ratio C_swan / C_std over L (b=128)\n");
    out.push_str(&format!("{:<8} {:>10} {:>10} {:>10}\n", "L", "k=32", "k=64", "k=96"));
    for l in [128usize, 256, 384, 512, 1024, 4096, 16384] {
        let row: Vec<f64> = [32usize, 64, 96]
            .iter()
            .map(|&k| flops_swan(l, 128, 128, k) as f64 / flops_std(l, 128) as f64)
            .collect();
        out.push_str(&format!(
            "{l:<8} {:>10.3} {:>10.3} {:>10.3}\n", row[0], row[1], row[2]));
    }

    out.push_str("\n## measured wallclock (rust sparse-dense vs dense attention)\n");
    out.push_str(&format!(
        "{:<8} {:>14} {:>14} {:>8}\n", "L", "dense/step", "swan/step", "ratio"));
    let d = 128usize;
    let mut rng = Pcg64::new(0);
    let q = rng.normal_vec(d);
    let kc = rng.normal_vec(d);
    let vc = rng.normal_vec(d);
    let mut crossover: Option<usize> = None;
    for l in [64usize, 128, 256, 512, 1024, 2048, 4096] {
        // dense cache
        let kflat = rng.normal_vec(l * d);
        let vflat = rng.normal_vec(l * d);
        let mut out_v = vec![0.0f32; d];
        let dense_t = bench_batched(3, 15, 4, || {
            crate::swan::attention::dense_attention(&q, &kflat, &vflat, &kc, &vc, d, &mut out_v);
            std::hint::black_box(&out_v);
        });
        // swan hybrid cache, k=32, b = min(128, l/2)
        let b = 128.min(l / 2);
        let mut cache = HybridCache::new(d, SwanParams::new(32, b, StorageMode::F32));
        for t in 0..l {
            cache.append(&kflat[t * d..(t + 1) * d], &vflat[t * d..(t + 1) * d]);
        }
        let proj = rng.normal_vec(d * d);
        let mut qr = vec![0.0f32; d];
        let mut kr = vec![0.0f32; d];
        let swan_t = bench_batched(3, 15, 4, || {
            // the runtime projection overhead (2 d_h^2 mat-vecs) is
            // charged to SWAN, exactly as in Proposition A.4
            crate::tensor::ops::vecmat(&q, &proj, d, d, &mut qr);
            crate::tensor::ops::vecmat(&kc, &proj, d, d, &mut kr);
            crate::swan::attention::swan_attention(&qr, &cache, &kr, &vc, &mut out_v);
            std::hint::black_box(&out_v);
        });
        let ratio = swan_t.median_ns / dense_t.median_ns;
        if ratio < 1.0 && crossover.is_none() {
            crossover = Some(l);
        }
        out.push_str(&format!(
            "{l:<8} {:>14} {:>14} {:>8.3}\n",
            crate::util::stats::Summary::fmt_time(dense_t.median_ns),
            crate::util::stats::Summary::fmt_time(swan_t.median_ns),
            ratio
        ));
    }
    out.push_str(&format!(
        "measured crossover: {} (formula, k=32 b=128: L* = 299)\n",
        crossover.map(|l| l.to_string()).unwrap_or_else(|| "not reached".into())
    ));
    ctx.emit("breakeven", out)
}

#[cfg(test)]
mod tests {
    #[test]
    fn closed_form_section_is_exact() {
        // pure-algebra part is covered in swan::breakeven tests; here we
        // just check the module runs end to end quickly
        let mut ctx = crate::repro::ReproCtx::new(std::env::temp_dir(), 1);
        ctx.results_dir = std::env::temp_dir().join("swan-results-test");
        let out = super::run(&mut ctx).unwrap();
        assert!(out.contains("counted L*"));
        assert!(out.contains("171"));
        assert!(out.contains("640"));
    }
}
