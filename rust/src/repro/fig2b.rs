//! Figure 2b: multi-step reasoning (GSM8K analogue) under compression.
//!
//! Paper findings to reproduce (shape, not absolute numbers):
//!   * zero-buffer variants collapse catastrophically;
//!   * bt=128 16-bit stays near baseline down to ~50% memory;
//!   * below ~40% ratio the 8-bit variant crosses over the 16-bit one
//!     (more, less-precise dims beat fewer precise ones).
//!
//! Task: few-shot chained arithmetic — any loss in the KV history breaks
//! the carried value, exactly GSM8K's failure mode.

use crate::eval::tasks::TaskCase;
use crate::eval::Harness;
use crate::kvcache::PolicyKind;
use crate::repro::ReproCtx;
use crate::sparse::StorageMode;
use crate::util::Pcg64;

/// Few-shot arithmetic prompt: 3 solved chains as context + 1 to finish.
pub fn fewshot_arith_cases(n: usize, steps: usize, seed: u64) -> Vec<TaskCase> {
    let mut rng = Pcg64::new(seed ^ 0x2b);
    (0..n)
        .map(|_| {
            let mut prompt = String::new();
            for _ in 0..3 {
                let (body, ans) = crate::eval::corpus::arith_chain(&mut rng, steps);
                prompt.push_str(&body);
                prompt.push_str(&ans);
                prompt.push_str(" . ");
            }
            let (body, answer) = crate::eval::corpus::arith_chain(&mut rng, steps);
            prompt.push_str(&body);
            TaskCase { prompt, answer }
        })
        .collect()
}

pub fn run(ctx: &mut ReproCtx) -> anyhow::Result<String> {
    let n_cases = ctx.cases.max(8);
    let cases = fewshot_arith_cases(n_cases, 5, 42);
    let out = body(ctx, &cases)?;
    ctx.emit("fig2b", out)
}

fn body(ctx: &mut ReproCtx, cases: &[TaskCase]) -> anyhow::Result<String> {
    let model = ctx.model("swan-nano-gqa")?;
    let mut h = Harness::new(model);

    let d_h = model.cfg.d_head;
    let ratios = [0.75f64, 0.5, 0.3, 0.2, 0.12, 0.06, 0.03];
    let mut out = String::from(
        "# Fig 2b — GSM8K-analogue (few-shot arithmetic chains) vs compression\n\n");
    let dense = h.run_cases("arith-fewshot", cases, PolicyKind::Dense);
    out.push_str(&format!("baseline (dense): accuracy {:.3}\n\n", dense.accuracy));
    out.push_str(&format!(
        "{:<10} {:>12} {:>12} {:>12} {:>12} | {:>10} {:>10}\n",
        "retention", "16b bt=128", "8b bt=128", "16b bt=0", "8b bt=0", "ratio16", "ratio8"
    ));
    for &r in &ratios {
        let k = ((r * d_h as f64).round() as usize).max(1);
        let mut cells = Vec::new();
        let mut ratio16 = 0.0;
        let mut ratio8 = 0.0;
        for (mode, bt) in [
            (StorageMode::F16, 128usize),
            (StorageMode::F8, 128),
            (StorageMode::F16, 0),
            (StorageMode::F8, 0),
        ] {
            let res = h.run_cases(
                "arith-fewshot",
                cases,
                PolicyKind::Swan { k_active: k, buffer: bt, mode },
            );
            if bt == 0 {
                if mode == StorageMode::F16 {
                    ratio16 = res.compression_ratio;
                } else {
                    ratio8 = res.compression_ratio;
                }
            }
            cells.push(res.accuracy);
        }
        out.push_str(&format!(
            "{:<10.2} {:>12.3} {:>12.3} {:>12.3} {:>12.3} | {:>10.3} {:>10.3}\n",
            r, cells[0], cells[1], cells[2], cells[3], ratio16, ratio8
        ));
    }
    out.push_str("\npaper shape: bt=0 collapses; bt=128 16-bit near-baseline to ~50%;\n\
                  8-bit overtakes 16-bit at aggressive ratios (crossover < 0.4).\n");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fewshot_cases_contain_three_examples() {
        let cases = fewshot_arith_cases(2, 4, 0);
        for c in &cases {
            assert_eq!(c.prompt.matches("start ").count(), 4);
            assert_eq!(c.prompt.matches("answer").count(), 4);
            assert!(c.prompt.ends_with("answer "));
            assert!(c.prompt.len() > 200, "prompt too short to stress the cache");
        }
    }
}
