//! Figure 2a: the compression-pruning trade-off.
//!
//! Paper series: effective memory ratio vs retention ratio for 16-bit and
//! 8-bit sparse values; the shaded region (ratio > 1) is where the sparse
//! form is *larger* than dense.  Paper facts to reproduce: 16-bit breaks
//! even at retention ≈ 0.66 (d_h = 128); 8-bit is "almost one-to-one".

use crate::repro::ReproCtx;
use crate::sparse::memory::{breakeven_retention, compression_ratio, StorageMode};

pub fn run(ctx: &mut ReproCtx) -> anyhow::Result<String> {
    let mut out = String::from(
        "# Fig 2a — compression vs pruning (memory ratio per stored vector)\n\n");
    for &d_h in &[128usize, 64] {
        out.push_str(&format!("## d_h = {d_h}\n"));
        out.push_str(&format!(
            "{:<10} {:>14} {:>14}\n", "retention", "16-bit ratio", "8-bit ratio"));
        let mut step = 0.05f64;
        let mut r = step;
        while r <= 1.0 + 1e-9 {
            let k = (r * d_h as f64).round() as usize;
            out.push_str(&format!(
                "{:<10.2} {:>14.3} {:>14.3}\n",
                r,
                compression_ratio(d_h, k, StorageMode::F16),
                compression_ratio(d_h, k, StorageMode::F8),
            ));
            if (r - 0.6).abs() < 1e-9 {
                step = 0.05; // uniform grid; kept for clarity
            }
            r += step;
        }
        let be16 = breakeven_retention(d_h, StorageMode::F16);
        let be8 = breakeven_retention(d_h, StorageMode::F8);
        out.push_str(&format!(
            "break-even retention: 16-bit {be16:.3} (paper: ~0.66 at d_h=128), \
             8-bit {be8:.3} (paper: almost 1.0)\n\n"
        ));
    }
    ctx.emit("fig2a", out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reproduces_paper_thresholds() {
        let mut ctx = ReproCtx::new(std::env::temp_dir().join("swan-none"), 1);
        ctx.results_dir = std::env::temp_dir().join("swan-results-test");
        let out = run(&mut ctx).unwrap();
        assert!(out.contains("d_h = 128"));
        // the 16-bit break-even row must be ~0.66
        assert!(out.contains("16-bit 0.66"), "{out}");
    }
}
