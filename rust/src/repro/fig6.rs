//! Figure 6: remaining LongBench tasks — code completion (LCC analogue),
//! long classification (TREC analogue via continuation choice over long
//! contexts), passage retrieval (passkey).
//!
//! Paper findings: the 128-token buffer is essential everywhere; buffered
//! variants trade off gracefully; TREC-style tasks drop sharply beyond
//! ~50% compression.

use crate::eval::tasks::{Task, TaskKind};
use crate::eval::{harness::format_table, Harness};
use crate::kvcache::PolicyKind;
use crate::repro::ReproCtx;
use crate::sparse::StorageMode;

pub fn run(ctx: &mut ReproCtx) -> anyhow::Result<String> {
    let n_cases = ctx.cases.max(5);
    let model = ctx.model("swan-nano-gqa")?;
    let mut h = Harness::new(model);
    let d_h = model.cfg.d_head;

    let tasks = vec![
        Task { kind: TaskKind::Code { clutter: 12 }, n_cases, seed: 60 },
        Task { kind: TaskKind::Passkey { distance: 280 }, n_cases, seed: 61 },
        Task { kind: TaskKind::LongRecall { distance: 320 }, n_cases, seed: 62 },
    ];

    let mut rows = Vec::new();
    let mut choice_rows = String::new();
    for t in &tasks {
        rows.push(h.run_task(t, PolicyKind::Dense));
    }
    // TREC-analogue: continuation choice over a long compressed context
    let dense_choice = h.continuation_choice(PolicyKind::Dense, n_cases, 260, 16, 7);
    choice_rows.push_str(&format!(
        "{:<34} {:>9.3}\n", "dense", dense_choice));

    for &r in &[0.5f64, 0.2, 0.08] {
        let k = ((r * d_h as f64).round() as usize).max(1);
        for (mode, bt) in [(StorageMode::F16, 128usize), (StorageMode::F8, 128), (StorageMode::F16, 0)] {
            let policy = PolicyKind::Swan { k_active: k, buffer: bt, mode };
            for t in &tasks {
                rows.push(h.run_task(t, policy));
            }
            let c = h.continuation_choice(policy, n_cases, 260, 16, 7);
            choice_rows.push_str(&format!("{:<34} {:>9.3}\n", policy.label(), c));
        }
    }
    let mut out = String::from("# Fig 6 — LCC / TREC / PassageRetrieval analogues\n\n");
    out.push_str(&format_table("generation tasks", &rows));
    out.push_str("\n## long-context continuation choice (TREC-classification analogue)\n");
    out.push_str(&format!("{:<34} {:>9}\n", "policy", "accuracy"));
    out.push_str(&choice_rows);
    out.push_str("\npaper shape: buffer essential; graceful buffered trade-off;\n\
                  classification-style scores drop sharply past ~50% compression.\n");
    ctx.emit("fig6", out)
}
