//! PJRT runtime: loads the AOT HLO-text graphs lowered by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! This is the serving hot path — python never runs at request time.
//!
//! * [`artifacts`] — manifest parsing + artifact discovery.
//! * [`engine`] — compiled-executable cache, device-resident weight
//!   buffers (uploaded once per model), typed execute helpers.

pub mod artifacts;
pub mod engine;

pub use artifacts::{ArtifactStore, GraphMeta};
pub use engine::{HostTensor, Runtime};
