//! Artifact discovery: parses `artifacts/manifest.json` into typed
//! metadata the engine uses to locate graphs, order weight parameters and
//! validate runtime argument shapes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::Context;

use crate::config::ModelConfig;
use crate::util::json::Json;

/// One runtime argument of a graph (after the weight parameters).
#[derive(Clone, Debug, PartialEq)]
pub struct ArgMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "float32" | "int32"
}

/// One AOT graph.
#[derive(Clone, Debug)]
pub struct GraphMeta {
    pub file: PathBuf,
    /// Weight tensors fed first, in this order.
    pub param_names: Vec<String>,
    /// Runtime arguments fed after the weights.
    pub args: Vec<ArgMeta>,
}

/// One model's artifact entry.
#[derive(Clone, Debug)]
pub struct ModelArtifacts {
    pub config: ModelConfig,
    pub weights: PathBuf,
    pub golden: PathBuf,
    pub buf: usize,
    pub graphs: BTreeMap<String, GraphMeta>,
}

impl ModelArtifacts {
    /// Decode graph buckets available, sorted: (sparse_len, k_active).
    pub fn decode_buckets(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for name in self.graphs.keys() {
            if let Some(rest) = name.strip_prefix("decode_l") {
                if let Some((l, k)) = rest.split_once("_k") {
                    if let (Ok(l), Ok(k)) = (l.parse(), k.parse()) {
                        out.push((l, k));
                    }
                }
            }
        }
        out.sort();
        out
    }

    /// Prefill buckets (token capacities) available, sorted.
    pub fn prefill_buckets(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .graphs
            .keys()
            .filter_map(|n| n.strip_prefix("prefill_t").and_then(|t| t.parse().ok()))
            .collect();
        out.sort();
        out
    }

    /// Smallest decode bucket holding `sparse_len` tokens at >= `k_active`
    /// retained dims; falls back to the largest bucket.
    pub fn pick_decode_bucket(&self, sparse_len: usize, k_active: usize) -> Option<(usize, usize)> {
        let buckets = self.decode_buckets();
        // exact-k preferred, else smallest k >= requested
        let ks: Vec<usize> = {
            let mut v: Vec<usize> = buckets.iter().map(|&(_, k)| k).collect();
            v.sort();
            v.dedup();
            v
        };
        let k = ks.iter().copied().find(|&k| k >= k_active).or(ks.last().copied())?;
        let ls: Vec<usize> = {
            let mut v: Vec<usize> =
                buckets.iter().filter(|&&(_, bk)| bk == k).map(|&(l, _)| l).collect();
            v.sort();
            v
        };
        let l = ls.iter().copied().find(|&l| l >= sparse_len).or(ls.last().copied())?;
        Some((l, k))
    }
}

/// The whole artifact directory.
pub struct ArtifactStore {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelArtifacts>,
    pub prune_graphs: BTreeMap<String, GraphMeta>,
}

fn parse_graphs(dir: &Path, j: &Json) -> anyhow::Result<BTreeMap<String, GraphMeta>> {
    let mut graphs = BTreeMap::new();
    for (gname, g) in j.as_obj().context("graphs not an object")? {
        let file = dir.join(
            g.get("file").and_then(Json::as_str).context("graph missing file")?,
        );
        let param_names = g
            .get("param_names")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|x| x.as_str().map(String::from)).collect())
            .unwrap_or_default();
        let mut args = Vec::new();
        for a in g.get("args").and_then(Json::as_arr).unwrap_or(&[]) {
            args.push(ArgMeta {
                name: a.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                shape: a
                    .get("shape")
                    .and_then(Json::as_arr)
                    .map(|s| s.iter().filter_map(Json::as_usize).collect())
                    .unwrap_or_default(),
                dtype: a.get("dtype").and_then(Json::as_str).unwrap_or("float32").to_string(),
            });
        }
        graphs.insert(gname.clone(), GraphMeta { file, param_names, args });
    }
    Ok(graphs)
}

impl ArtifactStore {
    pub fn load(dir: &Path) -> anyhow::Result<ArtifactStore> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;

        let mut models = BTreeMap::new();
        for (name, m) in j.get("models").and_then(Json::as_obj).context("manifest: models")? {
            let config = ModelConfig::from_json(m.get("config").context("model config")?)?;
            models.insert(
                name.clone(),
                ModelArtifacts {
                    config,
                    weights: dir.join(m.get("weights").and_then(Json::as_str).unwrap_or("")),
                    golden: dir.join(m.get("golden").and_then(Json::as_str).unwrap_or("")),
                    buf: m.get("buf").and_then(Json::as_usize).unwrap_or(64),
                    graphs: parse_graphs(dir, m.get("graphs").context("model graphs")?)?,
                },
            );
        }
        let prune_graphs = j
            .get("prune_graphs")
            .map(|g| parse_graphs(dir, g))
            .transpose()?
            .unwrap_or_default();
        Ok(ArtifactStore { dir: dir.to_path_buf(), models, prune_graphs })
    }

    pub fn model(&self, name: &str) -> anyhow::Result<&ModelArtifacts> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("model '{name}' not in manifest ({:?})",
                                           self.models.keys().collect::<Vec<_>>()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest() -> Json {
        Json::parse(
            r#"{"models": {"m": {
                "config": {"name":"m","d_model":256,"n_layers":4,"n_q_heads":4,
                           "n_kv_heads":1,"d_head":64,"d_ff":1024,"vocab":96},
                "weights": "w.bin", "golden": "g.bin", "buf": 64,
                "graphs": {
                  "decode_l128_k16": {"file":"a.hlo.txt","param_names":["embed"],"args":[]},
                  "decode_l128_k32": {"file":"b.hlo.txt","param_names":[],"args":[]},
                  "decode_l512_k32": {"file":"c.hlo.txt","param_names":[],"args":[]},
                  "prefill_t64": {"file":"d.hlo.txt","param_names":[],
                     "args":[{"name":"tokens","shape":[64],"dtype":"int32"}]}
                }}}}"#,
        )
        .unwrap()
    }

    fn fake_store() -> ArtifactStore {
        let j = fake_manifest();
        let dir = Path::new("/tmp/fake");
        let mut models = BTreeMap::new();
        for (name, m) in j.get("models").and_then(Json::as_obj).unwrap() {
            models.insert(
                name.clone(),
                ModelArtifacts {
                    config: ModelConfig::from_json(m.get("config").unwrap()).unwrap(),
                    weights: dir.join("w.bin"),
                    golden: dir.join("g.bin"),
                    buf: 64,
                    graphs: parse_graphs(dir, m.get("graphs").unwrap()).unwrap(),
                },
            );
        }
        ArtifactStore { dir: dir.into(), models, prune_graphs: BTreeMap::new() }
    }

    #[test]
    fn decode_buckets_parsed() {
        let s = fake_store();
        let m = s.model("m").unwrap();
        assert_eq!(m.decode_buckets(), vec![(128, 16), (128, 32), (512, 32)]);
        assert_eq!(m.prefill_buckets(), vec![64]);
    }

    #[test]
    fn bucket_picking() {
        let s = fake_store();
        let m = s.model("m").unwrap();
        assert_eq!(m.pick_decode_bucket(100, 32), Some((128, 32)));
        assert_eq!(m.pick_decode_bucket(200, 32), Some((512, 32)));
        // k above available: falls back to largest k
        assert_eq!(m.pick_decode_bucket(100, 64), Some((128, 32)));
        // l above available: falls back to largest bucket
        assert_eq!(m.pick_decode_bucket(9999, 16), Some((128, 16)));
    }

    #[test]
    fn args_parsed() {
        let s = fake_store();
        let g = &s.model("m").unwrap().graphs["prefill_t64"];
        assert_eq!(g.args[0].name, "tokens");
        assert_eq!(g.args[0].shape, vec![64]);
        assert_eq!(g.args[0].dtype, "int32");
    }

    #[test]
    fn missing_model_errors() {
        assert!(fake_store().model("nope").is_err());
    }
}
