//! PJRT execution engine.
//!
//! Responsibilities:
//! * one CPU PJRT client per process;
//! * lazy compile cache: HLO text -> `PjRtLoadedExecutable`, keyed by
//!   (model, graph) — mirrors vLLM's CUDA-graph pool over shape buckets;
//! * device-resident weight buffers, uploaded once per model and reused by
//!   every request (`execute_b`);
//! * typed host tensors for runtime arguments and outputs.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Mutex;

use anyhow::Context;

use crate::model::weights::{TensorData, WeightFile};
use crate::runtime::artifacts::{ArtifactStore, GraphMeta};
use crate::util::sync::lock_recover;

/// A host-side tensor fed to / read from a graph.
#[derive(Clone, Debug, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32(vec![v], vec![])
    }

    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> HostTensor {
        HostTensor::F32(data, shape)
    }

    pub fn i32(data: Vec<i32>, shape: Vec<usize>) -> HostTensor {
        HostTensor::I32(data, shape)
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            _ => anyhow::bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match self {
            HostTensor::I32(d, _) => Ok(d),
            _ => anyhow::bail!("expected i32 tensor"),
        }
    }

    fn from_literal(lit: &xla::Literal) -> anyhow::Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        Ok(match shape.ty() {
            xla::ElementType::F32 => HostTensor::F32(lit.to_vec::<f32>()?, dims),
            xla::ElementType::S32 => HostTensor::I32(lit.to_vec::<i32>()?, dims),
            other => anyhow::bail!("unsupported output element type {other:?}"),
        })
    }
}

/// Borrowed view of a runtime argument — lets the serving hot path feed
/// its live cache arrays without cloning them every decode step (§Perf
/// L3 optimization; see EXPERIMENTS.md).
#[derive(Clone, Copy, Debug)]
pub enum ArgView<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

impl<'a> ArgView<'a> {
    pub fn shape(&self) -> &'a [usize] {
        match self {
            ArgView::F32(_, s) | ArgView::I32(_, s) => s,
        }
    }

    pub fn from_host(t: &'a HostTensor) -> ArgView<'a> {
        match t {
            HostTensor::F32(d, s) => ArgView::F32(d, s),
            HostTensor::I32(d, s) => ArgView::I32(d, s),
        }
    }
}

struct CompiledGraph {
    exe: xla::PjRtLoadedExecutable,
    n_params: usize,
}

/// The process-wide PJRT runtime.
pub struct Runtime {
    client: xla::PjRtClient,
    /// (model, graph) -> compiled executable.
    compiled: Mutex<BTreeMap<(String, String), std::sync::Arc<CompiledGraph>>>,
    /// model -> device-resident weight buffers in manifest param order.
    weights: Mutex<BTreeMap<String, std::sync::Arc<Vec<xla::PjRtBuffer>>>>,
}

// The PJRT CPU client is thread-safe for compilation/execution.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    pub fn new() -> anyhow::Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            compiled: Mutex::new(BTreeMap::new()),
            weights: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Upload a model's weights (from its container) as device buffers in
    /// the given parameter order; cached per model name.
    pub fn ensure_weights(
        &self,
        model: &str,
        wf: &WeightFile,
        param_names: &[String],
    ) -> anyhow::Result<()> {
        let mut guard = lock_recover(&self.weights);
        if guard.contains_key(model) {
            return Ok(());
        }
        let mut bufs = Vec::with_capacity(param_names.len());
        for name in param_names {
            let t = wf.get(name)?;
            let buf = match &t.data {
                TensorData::F32(d) => {
                    self.client.buffer_from_host_buffer(d, &t.shape, None)?
                }
                TensorData::I32(d) => {
                    self.client.buffer_from_host_buffer(d, &t.shape, None)?
                }
            };
            bufs.push(buf);
        }
        guard.insert(model.to_string(), std::sync::Arc::new(bufs));
        Ok(())
    }

    fn compile(&self, model: &str, graph: &str, meta: &GraphMeta) -> anyhow::Result<std::sync::Arc<CompiledGraph>> {
        {
            let guard = lock_recover(&self.compiled);
            if let Some(c) = guard.get(&(model.to_string(), graph.to_string())) {
                return Ok(c.clone());
            }
        }
        let path = meta.file.to_string_lossy().to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow::anyhow!("parsing HLO {}: {e:?}", path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow::anyhow!("compiling {graph}: {e:?}"))?;
        let compiled = std::sync::Arc::new(CompiledGraph { exe, n_params: meta.param_names.len() });
        lock_recover(&self.compiled)
            .insert((model.to_string(), graph.to_string()), compiled.clone());
        Ok(compiled)
    }

    /// Pre-compile a graph (startup warmup).
    pub fn warmup(&self, model: &str, graph: &str, meta: &GraphMeta) -> anyhow::Result<()> {
        self.compile(model, graph, meta).map(|_| ())
    }

    /// Number of compiled graphs currently cached.
    pub fn compiled_count(&self) -> usize {
        lock_recover(&self.compiled).len()
    }

    /// Execute `graph` of `model`: weight buffers (if the graph takes
    /// parameters) followed by `args`.  Returns the flattened tuple
    /// outputs.
    pub fn execute(
        &self,
        model: &str,
        graph: &str,
        meta: &GraphMeta,
        args: &[HostTensor],
    ) -> anyhow::Result<Vec<HostTensor>> {
        let views: Vec<ArgView> = args.iter().map(ArgView::from_host).collect();
        self.execute_views(model, graph, meta, &views)
    }

    /// Zero-copy variant of [`Runtime::execute`]: arguments are borrowed
    /// slices uploaded straight to device buffers (the decode hot path
    /// feeds its live cache arrays this way — no per-step cloning).
    pub fn execute_views(
        &self,
        model: &str,
        graph: &str,
        meta: &GraphMeta,
        args: &[ArgView<'_>],
    ) -> anyhow::Result<Vec<HostTensor>> {
        anyhow::ensure!(
            args.len() == meta.args.len(),
            "graph {graph}: expected {} runtime args, got {}",
            meta.args.len(),
            args.len()
        );
        for (a, m) in args.iter().zip(&meta.args) {
            anyhow::ensure!(
                a.shape() == m.shape.as_slice(),
                "graph {graph} arg '{}': shape {:?} != expected {:?}",
                m.name,
                a.shape(),
                m.shape
            );
        }
        let compiled = self.compile(model, graph, meta)?;

        let mut arg_bufs = Vec::with_capacity(args.len());
        for a in args {
            arg_bufs.push(match a {
                ArgView::F32(d, s) => self.client.buffer_from_host_buffer(d, s, None)?,
                ArgView::I32(d, s) => self.client.buffer_from_host_buffer(d, s, None)?,
            });
        }
        let out = if compiled.n_params > 0 {
            let wguard = lock_recover(&self.weights);
            let weights = wguard
                .get(model)
                .ok_or_else(|| anyhow::anyhow!("weights for '{model}' not uploaded"))?
                .clone();
            drop(wguard);
            // weights stay device-resident; runtime args were uploaded above
            let all: Vec<&xla::PjRtBuffer> = weights.iter().chain(arg_bufs.iter()).collect();
            compiled.exe.execute_b(&all).map_err(|e| anyhow::anyhow!("execute {graph}: {e:?}"))?
        } else {
            let refs: Vec<&xla::PjRtBuffer> = arg_bufs.iter().collect();
            compiled.exe.execute_b(&refs).map_err(|e| anyhow::anyhow!("execute {graph}: {e:?}"))?
        };

        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result of {graph}: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow::anyhow!("untuple {graph}: {e:?}"))?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

/// Convenience: open the artifact store + runtime together.
pub struct LoadedModel {
    pub store: ArtifactStore,
    pub runtime: Runtime,
    pub model: String,
}

impl LoadedModel {
    pub fn open(dir: &Path, model: &str) -> anyhow::Result<LoadedModel> {
        let store = ArtifactStore::load(dir)?;
        let runtime = Runtime::new()?;
        let arts = store.model(model)?;
        let wf = WeightFile::load(&arts.weights)?;
        // all graphs share the same param ordering; take any decode graph
        let names = arts
            .graphs
            .values()
            .find(|g| !g.param_names.is_empty())
            .map(|g| g.param_names.clone())
            .unwrap_or_default();
        runtime.ensure_weights(model, &wf, &names)?;
        Ok(LoadedModel { store, runtime, model: model.to_string() })
    }

    pub fn graph(&self, name: &str) -> anyhow::Result<&GraphMeta> {
        self.store
            .model(&self.model)?
            .graphs
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("graph '{name}' missing"))
    }

    pub fn execute(&self, graph: &str, args: &[HostTensor]) -> anyhow::Result<Vec<HostTensor>> {
        let meta = self.graph(graph)?;
        self.runtime.execute(&self.model, graph, meta, args)
    }

    /// Zero-copy execute (serving hot path).  Takes `&self` and the PJRT
    /// client is thread-safe, so the engine's decode worker pool calls
    /// this concurrently, one sequence per task; the graph metadata is
    /// borrowed (no more per-step `GraphMeta` clone of every param name
    /// and arg shape).
    pub fn execute_views(&self, graph: &str, args: &[ArgView<'_>]) -> anyhow::Result<Vec<HostTensor>> {
        let meta = self.graph(graph)?;
        self.runtime.execute_views(&self.model, graph, meta, args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_accessors() {
        let t = HostTensor::f32(vec![1.0, 2.0], vec![2]);
        assert_eq!(t.shape(), &[2]);
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
        let s = HostTensor::scalar_i32(7);
        assert_eq!(s.shape(), &[] as &[usize]);
    }

    // PJRT-dependent tests live in rust/tests/runtime_integration.rs where
    // they can be skipped when artifacts are absent.
}
