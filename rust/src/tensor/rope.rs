//! Rotary positional embeddings, matching `python/compile/model.py`
//! (`apply_rope`): pairs are (x[2i], x[2i+1]), angle(pos, i) =
//! pos * theta^(-2i/d).

/// Apply RoPE in place to a single head vector `x[d]` at position `pos`.
pub fn apply_rope(x: &mut [f32], pos: u32, theta: f32) {
    let d = x.len();
    debug_assert!(d % 2 == 0);
    let half = d / 2;
    for i in 0..half {
        let freq = theta.powf(-(2.0 * i as f32) / d as f32);
        let ang = pos as f32 * freq;
        let (s, c) = ang.sin_cos();
        let a = x[2 * i];
        let b = x[2 * i + 1];
        x[2 * i] = a * c - b * s;
        x[2 * i + 1] = a * s + b * c;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::dot;
    use crate::util::Pcg64;

    #[test]
    fn position_zero_is_identity() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        let orig = x.clone();
        apply_rope(&mut x, 0, 10000.0);
        assert_eq!(x, orig);
    }

    #[test]
    fn preserves_norm() {
        let mut r = Pcg64::new(0);
        for pos in [1u32, 7, 100, 5000] {
            let mut x = r.normal_vec(64);
            let norm0 = dot(&x, &x);
            apply_rope(&mut x, pos, 10000.0);
            let norm1 = dot(&x, &x);
            assert!((norm0 - norm1).abs() / norm0 < 1e-4);
        }
    }

    #[test]
    fn relative_position_property() {
        // <R_m q, R_n k> depends only on m - n
        let mut r = Pcg64::new(1);
        let q0 = r.normal_vec(32);
        let k0 = r.normal_vec(32);
        let score = |m: u32, n: u32| {
            let mut q = q0.clone();
            let mut k = k0.clone();
            apply_rope(&mut q, m, 10000.0);
            apply_rope(&mut k, n, 10000.0);
            dot(&q, &k)
        };
        assert!((score(3, 1) - score(10, 8)).abs() < 1e-3);
        assert!((score(6, 6) - score(0, 0)).abs() < 1e-3);
        assert!((score(5, 0) - score(9, 4)).abs() < 1e-3);
    }
}
