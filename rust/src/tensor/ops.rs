//! Core dense operations.  Row-major `&[f32]` slices with explicit shapes;
//! no generic tensor type — the model is small and the call sites are
//! explicit about layout, which keeps the hot paths allocation-free.
//!
//! The hot primitives (`dot`, `vecmat`, `softmax_inplace`, `rmsnorm`)
//! delegate to the process-wide [`crate::simd`] kernel set — scalar or
//! AVX2+FMA, selected once at startup — so every caller (model, attention,
//! cache policies, batch decode, shard engines) picks the SIMD path up
//! transparently.  Signatures and semantics are unchanged; the scalar
//! path is bit-identical to the pre-dispatch implementations.

/// y[m] += a[m,n] @ x[n]  (row-major `a`).
pub fn matvec_acc(a: &[f32], x: &[f32], m: usize, n: usize, y: &mut [f32]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(x.len(), n);
    debug_assert_eq!(y.len(), m);
    for i in 0..m {
        let row = &a[i * n..(i + 1) * n];
        y[i] += dot(row, x);
    }
}

/// y[m] = a[m,n] @ x[n].
pub fn matvec(a: &[f32], x: &[f32], m: usize, n: usize, y: &mut [f32]) {
    y.iter_mut().for_each(|v| *v = 0.0);
    matvec_acc(a, x, m, n, y);
}

/// y[n] = x[m] @ a[m,n]  (vector-matrix; the layout used by `x @ W`).
pub fn vecmat(x: &[f32], a: &[f32], m: usize, n: usize, y: &mut [f32]) {
    crate::simd::active().vecmat(x, a, m, n, y);
}

/// c[m,n] = a[m,k] @ b[k,n].
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let aip = a[i * k + p];
            if aip == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for (cij, bpj) in crow.iter_mut().zip(brow) {
                *cij += aip * bpj;
            }
        }
    }
    c
}

/// Dot product (the single hottest primitive in the dense baselines);
/// 4-wide-unrolled scalar or 8-lane AVX2 FMA per the active kernel set.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    crate::simd::active().dot(a, b)
}

/// In-place numerically-stable softmax.
pub fn softmax_inplace(x: &mut [f32]) {
    crate::simd::active().softmax_inplace(x);
}

/// RMSNorm: x * rsqrt(mean(x^2) + eps) * w.
pub fn rmsnorm(x: &[f32], w: &[f32], eps: f32, out: &mut [f32]) {
    crate::simd::active().rmsnorm(x, w, eps, out);
}

/// GELU (tanh approximation, matching jax.nn.gelu's default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// argmax of a slice.
pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    for i in 1..x.len() {
        if x[i] > x[best] {
            best = i;
        }
    }
    best
}

/// log-sum-exp of a slice (stable).
pub fn logsumexp(x: &[f32]) -> f32 {
    let m = x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    if !m.is_finite() {
        return m;
    }
    m + x.iter().map(|&v| (v - m).exp()).sum::<f32>().ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_identity() {
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let mut y = vec![0.0; 2];
        matvec(&a, &[3.0, 4.0], 2, 2, &mut y);
        assert_eq!(y, vec![3.0, 4.0]);
    }

    #[test]
    fn vecmat_matches_matvec_transpose() {
        let mut r = crate::util::Pcg64::new(0);
        let (m, n) = (7, 5);
        let a = r.normal_vec(m * n);
        let x = r.normal_vec(m);
        let mut y1 = vec![0.0; n];
        vecmat(&x, &a, m, n, &mut y1);
        // transpose a then matvec
        let mut at = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                at[j * m + i] = a[i * n + j];
            }
        }
        let mut y2 = vec![0.0; n];
        matvec(&at, &x, n, m, &mut y2);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn matmul_known() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let c = matmul(&[1.0, 2.0, 3.0, 4.0], &[1.0, 1.0, 1.0, 1.0], 2, 2, 2);
        assert_eq!(c, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn softmax_properties() {
        let mut x = vec![1.0, 2.0, 3.0];
        softmax_inplace(&mut x);
        assert!((x.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(x[2] > x[1] && x[1] > x[0]);
        // shift invariance
        let mut y = vec![1001.0, 1002.0, 1003.0];
        softmax_inplace(&mut y);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn softmax_with_neg_inf_mask() {
        let mut x = vec![f32::NEG_INFINITY, 0.0, f32::NEG_INFINITY];
        softmax_inplace(&mut x);
        assert_eq!(x[1], 1.0);
        assert_eq!(x[0], 0.0);
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![2.0f32; 8];
        let w = vec![1.0f32; 8];
        let mut out = vec![0.0; 8];
        rmsnorm(&x, &w, 0.0, &mut out);
        for &o in &out {
            assert!((o - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn gelu_reference_points() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(100.0) - 100.0).abs() < 1e-3);
        assert!(gelu(-100.0).abs() < 1e-3);
        // jax.nn.gelu(1.0) ≈ 0.841192
        assert!((gelu(1.0) - 0.841192).abs() < 1e-4);
    }

    #[test]
    fn dot_matches_naive() {
        let mut r = crate::util::Pcg64::new(1);
        for n in [1usize, 3, 4, 7, 64, 129] {
            let a = r.normal_vec(n);
            let b = r.normal_vec(n);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-3, "n={n}");
        }
    }

    #[test]
    fn logsumexp_stable() {
        let x = vec![1000.0f32, 1000.0];
        let l = logsumexp(&x);
        assert!((l - (1000.0 + (2.0f32).ln())).abs() < 1e-3);
    }
}
