//! Dense f32 tensor substrate for the rust-native model and experiment
//! harness: matmul/mat-vec, softmax, RMSNorm, RoPE, and a one-sided Jacobi
//! SVD (used for on-the-fly calibration and the random-projection ablation).

pub mod linalg;
pub mod ops;
pub mod rope;

pub use linalg::{gram_schmidt_orthonormal, svd_right_basis};
pub use ops::*;
pub use rope::apply_rope;
