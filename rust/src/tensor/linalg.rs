//! Dense linear algebra: one-sided Jacobi SVD (right-singular basis) and
//! Gram-Schmidt orthonormalisation.
//!
//! Used by the Table-3 ablations: the rust side can (a) re-derive
//! data-driven projections from rust-collected activations, and (b) build
//! the "Random Projection" baseline by orthonormalising Gaussian matrices.

/// Right-singular basis of `a` [m, n] (row-major): returns V [n, n]
/// column-orthonormal, with columns ordered by descending singular value —
/// the same object `numpy.linalg.svd(...).Vh.T` gives the python
/// calibration pipeline.
///
/// One-sided Jacobi on A^T A via implicit rotations of V; O(n^2 m) per
/// sweep, fine for the d_h <= 128 matrices SWAN uses.
pub fn svd_right_basis(a: &[f32], m: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * n);
    // Work on B = A^T A (n x n symmetric, f64 for stability), diagonalise
    // with cyclic Jacobi: B <- J^T B J accumulating V <- V J.
    let mut b = vec![0.0f64; n * n];
    for i in 0..m {
        let row = &a[i * n..(i + 1) * n];
        for p in 0..n {
            let rp = row[p] as f64;
            if rp == 0.0 {
                continue;
            }
            for q in p..n {
                b[p * n + q] += rp * row[q] as f64;
            }
        }
    }
    for p in 0..n {
        for q in 0..p {
            b[p * n + q] = b[q * n + p];
        }
    }

    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let max_sweeps = 60;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                off += b[p * n + q] * b[p * n + q];
            }
        }
        let norm: f64 = (0..n).map(|i| b[i * n + i] * b[i * n + i]).sum();
        if off <= 1e-24 * norm.max(1e-300) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let bpq = b[p * n + q];
                if bpq.abs() < 1e-300 {
                    continue;
                }
                let bpp = b[p * n + p];
                let bqq = b[q * n + q];
                let tau = (bqq - bpp) / (2.0 * bpq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // B <- J^T B J (rows/cols p, q)
                for i in 0..n {
                    let bip = b[i * n + p];
                    let biq = b[i * n + q];
                    b[i * n + p] = c * bip - s * biq;
                    b[i * n + q] = s * bip + c * biq;
                }
                for i in 0..n {
                    let bpi = b[p * n + i];
                    let bqi = b[q * n + i];
                    b[p * n + i] = c * bpi - s * bqi;
                    b[q * n + i] = s * bpi + c * bqi;
                }
                // V <- V J
                for i in 0..n {
                    let vip = v[i * n + p];
                    let viq = v[i * n + q];
                    v[i * n + p] = c * vip - s * viq;
                    v[i * n + q] = s * vip + c * viq;
                }
            }
        }
    }

    // sort columns by descending eigenvalue (diagonal of B)
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&x, &y| b[y * n + y].partial_cmp(&b[x * n + x]).unwrap());
    let mut out = vec![0.0f32; n * n];
    for (new_c, &old_c) in order.iter().enumerate() {
        for r in 0..n {
            out[r * n + new_c] = v[r * n + old_c] as f32;
        }
    }
    out
}

/// Orthonormalise the columns of `a` [n, n] in place via modified
/// Gram-Schmidt; used for the Random-Projection ablation baseline.
pub fn gram_schmidt_orthonormal(a: &mut [f32], n: usize) {
    assert_eq!(a.len(), n * n);
    for c in 0..n {
        // subtract projections on previous columns (twice for stability)
        for _ in 0..2 {
            for prev in 0..c {
                let mut proj = 0.0f64;
                for r in 0..n {
                    proj += a[r * n + c] as f64 * a[r * n + prev] as f64;
                }
                for r in 0..n {
                    a[r * n + c] -= (proj as f32) * a[r * n + prev];
                }
            }
        }
        let mut norm = 0.0f64;
        for r in 0..n {
            norm += (a[r * n + c] as f64).powi(2);
        }
        let inv = 1.0 / norm.sqrt().max(1e-30) as f32;
        for r in 0..n {
            a[r * n + c] *= inv;
        }
    }
}

/// Check `v^T v == I` within `tol`; returns max deviation.
pub fn orthonormality_error(v: &[f32], n: usize) -> f32 {
    let mut worst = 0.0f32;
    for c1 in 0..n {
        for c2 in c1..n {
            let mut d = 0.0f64;
            for r in 0..n {
                d += v[r * n + c1] as f64 * v[r * n + c2] as f64;
            }
            let target = if c1 == c2 { 1.0 } else { 0.0 };
            worst = worst.max((d - target).abs() as f32);
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn svd_basis_orthonormal() {
        let mut r = Pcg64::new(0);
        let (m, n) = (50, 16);
        let a = r.normal_vec(m * n);
        let v = svd_right_basis(&a, m, n);
        assert!(orthonormality_error(&v, n) < 1e-4);
    }

    #[test]
    fn svd_energy_descending() {
        let mut r = Pcg64::new(1);
        let (m, n) = (200, 12);
        let a = r.normal_vec(m * n);
        let v = svd_right_basis(&a, m, n);
        // project rows of a onto v; column energies must descend
        let mut energy = vec![0.0f64; n];
        for i in 0..m {
            for c in 0..n {
                let mut p = 0.0f64;
                for j in 0..n {
                    p += a[i * n + j] as f64 * v[j * n + c] as f64;
                }
                energy[c] += p * p;
            }
        }
        for c in 1..n {
            assert!(
                energy[c] <= energy[c - 1] + 1e-6,
                "energy not descending at {c}: {energy:?}"
            );
        }
    }

    #[test]
    fn svd_concentrates_planted_lowrank() {
        // rank-3 signal + small noise: first 3 dirs must hold >90% energy
        let mut r = Pcg64::new(2);
        let (m, n, rank) = (300, 16, 3);
        let basis = r.normal_vec(rank * n);
        let mut a = vec![0.0f32; m * n];
        for i in 0..m {
            let coef = r.normal_vec(rank);
            for j in 0..n {
                let mut x = 0.0;
                for k in 0..rank {
                    x += coef[k] * basis[k * n + j];
                }
                a[i * n + j] = x + 0.01 * r.normal_f32();
            }
        }
        let v = svd_right_basis(&a, m, n);
        let mut energy = vec![0.0f64; n];
        for i in 0..m {
            for c in 0..n {
                let mut p = 0.0f64;
                for j in 0..n {
                    p += a[i * n + j] as f64 * v[j * n + c] as f64;
                }
                energy[c] += p * p;
            }
        }
        let lead: f64 = energy[..rank].iter().sum();
        let total: f64 = energy.iter().sum();
        assert!(lead / total > 0.9, "lead fraction {}", lead / total);
    }

    #[test]
    fn gram_schmidt_orthonormalises() {
        let mut r = Pcg64::new(3);
        let n = 24;
        let mut a = r.normal_vec(n * n);
        gram_schmidt_orthonormal(&mut a, n);
        assert!(orthonormality_error(&a, n) < 1e-4);
    }

    #[test]
    fn rotation_by_svd_basis_preserves_dots() {
        // orthogonality of V means q.k == (qV).(kV) — Lemma A.1 in rust
        let mut r = Pcg64::new(4);
        let n = 16;
        let a = r.normal_vec(100 * n);
        let v = svd_right_basis(&a, 100, n);
        let q = r.normal_vec(n);
        let k = r.normal_vec(n);
        let rot = |x: &[f32]| -> Vec<f32> {
            (0..n)
                .map(|c| (0..n).map(|j| x[j] * v[j * n + c]).sum())
                .collect()
        };
        let d0 = crate::tensor::ops::dot(&q, &k);
        let d1 = crate::tensor::ops::dot(&rot(&q), &rot(&k));
        assert!((d0 - d1).abs() < 1e-3, "{d0} vs {d1}");
    }
}
