//! Request-lifecycle tracing.
//!
//! A [`Trace`] rides inside each `Request` and is owned by the one
//! coordinator thread that drives that request, so recording an event
//! is a plain `Vec::push` — no lock, no atomics, nothing shared. Only
//! at retire (or cancel-purge) does the finished trace get pushed into
//! the engine's bounded [`TraceRing`], which *is* mutex-guarded but is
//! touched once per request lifetime, never per token.
//!
//! The `TRACE <id>` wire verb renders a retained trace as JSONL — one
//! event object per line — for offline timeline reconstruction.

use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::sync::lock_recover;

/// Cap on events per trace: a preempted long generation records one
/// `Decode` event per committed token, so bound the vector and count
/// drops instead of growing without limit.
pub const MAX_TRACE_EVENTS: usize = 4096;

/// Default retired-trace retention per engine/group.
pub const TRACE_RING_CAP: usize = 256;

/// Lifecycle event kinds, in the order a healthy request emits them.
/// `Preempt`/`Resume` pairs may repeat; `Decode` repeats per token.
/// `Die`/`Recover` bracket a shard death: the request's shard died with
/// the sequence in flight, and a healthy shard picked it up (the
/// cross-shard generalization of the preempt→resume arc).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// Request accepted by submit(): id assigned, queued.
    Submit,
    /// Popped from the scheduler queue into the active batch.
    Admit,
    /// Admission matched a cached prompt prefix: its blocks attach
    /// copy-on-write and prefill covers only the uncached suffix.
    PrefixHit,
    /// Prompt prefill finished (also re-prefill on preemption resume).
    PrefillDone,
    /// First generated token committed (TTFT point).
    FirstToken,
    /// One decode-iteration token committed.
    Decode,
    /// Evicted mid-flight (blocks reclaimed, requeued at front).
    Preempt,
    /// Re-admitted after preemption; replay rebuild starts.
    Resume,
    /// The owning shard died (panic / stage failure / drain migration);
    /// the request was extracted for recovery.
    Die,
    /// Handed to a healthy shard; re-prefill + replay follow (the
    /// resumed stream is bit-identical to an uninterrupted run).
    Recover,
    /// Final: completed, cancelled, or purged.
    Retire,
}

impl TraceKind {
    pub fn name(self) -> &'static str {
        match self {
            TraceKind::Submit => "submit",
            TraceKind::Admit => "admit",
            TraceKind::PrefixHit => "prefix_hit",
            TraceKind::PrefillDone => "prefill_done",
            TraceKind::FirstToken => "first_token",
            TraceKind::Decode => "decode",
            TraceKind::Preempt => "preempt",
            TraceKind::Resume => "resume",
            TraceKind::Die => "die",
            TraceKind::Recover => "recover",
            TraceKind::Retire => "retire",
        }
    }
}

/// One timestamped lifecycle event, offset from the trace origin.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    pub t_ns: u64,
    pub kind: TraceKind,
}

/// Per-request event timeline. Cloneable plain data (the origin is a
/// monotonic `Instant`); single-owner, so recording never synchronizes.
#[derive(Clone, Debug)]
pub struct Trace {
    /// Request id; 0 until `begin` stamps it at submit time.
    pub id: u64,
    start: Instant,
    events: Vec<TraceEvent>,
    /// Events discarded past `MAX_TRACE_EVENTS`.
    dropped: u64,
}

impl Default for Trace {
    fn default() -> Trace {
        Trace::new()
    }
}

impl Trace {
    pub fn new() -> Trace {
        Trace { id: 0, start: Instant::now(), events: Vec::new(), dropped: 0 }
    }

    /// Stamp the assigned request id and record the `Submit` event.
    /// Re-anchors the origin so `t_ns` offsets start at submission.
    pub fn begin(&mut self, id: u64) {
        self.id = id;
        self.start = Instant::now();
        self.record(TraceKind::Submit);
    }

    /// Record one event at "now". Bounded: past `MAX_TRACE_EVENTS` the
    /// event is counted in `dropped` instead (the terminal `Retire` is
    /// always kept so lifecycles stay complete).
    #[inline]
    pub fn record(&mut self, kind: TraceKind) {
        if self.events.len() >= MAX_TRACE_EVENTS && kind != TraceKind::Retire {
            self.dropped += 1;
            return;
        }
        let t_ns = self.start.elapsed().as_nanos().min(u64::MAX as u128) as u64;
        self.events.push(TraceEvent { t_ns, kind });
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Offset of the most recent event of `kind`, if any.
    pub fn last_ns(&self, kind: TraceKind) -> Option<u64> {
        self.events.iter().rev().find(|e| e.kind == kind).map(|e| e.t_ns)
    }

    /// Render as JSONL: one `{"id":..,"event":..,"t_ns":..}` object per
    /// line, in recording order; a final `{"id":..,"dropped":N}` line
    /// appears only when events were discarded.
    pub fn jsonl(&self) -> String {
        let mut out = String::with_capacity(self.events.len() * 48);
        for e in &self.events {
            out.push_str(&format!(
                "{{\"id\":{},\"event\":\"{}\",\"t_ns\":{}}}\n",
                self.id,
                e.kind.name(),
                e.t_ns
            ));
        }
        if self.dropped > 0 {
            out.push_str(&format!("{{\"id\":{},\"dropped\":{}}}\n", self.id, self.dropped));
        }
        out
    }
}

/// Bounded ring of retired traces, newest-kept. Mutex-guarded, but only
/// touched at request retire/lookup — never on the per-token path.
#[derive(Debug)]
pub struct TraceRing {
    cap: usize,
    inner: Mutex<VecDeque<Trace>>,
}

impl TraceRing {
    pub fn new(cap: usize) -> TraceRing {
        TraceRing { cap: cap.max(1), inner: Mutex::new(VecDeque::new()) }
    }

    /// Retain `trace`, evicting the oldest retained trace when full.
    pub fn push(&self, trace: Trace) {
        let mut ring = lock_recover(&self.inner);
        if ring.len() == self.cap {
            ring.pop_front();
        }
        ring.push_back(trace);
    }

    /// JSONL timeline for request `id`, if still retained.
    pub fn jsonl(&self, id: u64) -> Option<String> {
        let ring = lock_recover(&self.inner);
        ring.iter().rev().find(|t| t.id == id).map(|t| t.jsonl())
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.inner).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_caps_and_serves_newest() {
        let ring = TraceRing::new(2);
        for id in 1..=3u64 {
            let mut t = Trace::new();
            t.begin(id);
            t.record(TraceKind::Retire);
            ring.push(t);
        }
        assert_eq!(ring.len(), 2);
        assert!(ring.jsonl(1).is_none(), "oldest evicted");
        let j = ring.jsonl(3).expect("newest retained");
        assert!(j.contains("\"event\":\"submit\""));
        assert!(j.contains("\"event\":\"retire\""));
        assert!(j.lines().all(|l| l.starts_with('{') && l.ends_with('}')));
    }

    #[test]
    fn event_cap_keeps_retire() {
        let mut t = Trace::new();
        t.begin(9);
        for _ in 0..(MAX_TRACE_EVENTS + 10) {
            t.record(TraceKind::Decode);
        }
        t.record(TraceKind::Retire);
        assert_eq!(t.events().len(), MAX_TRACE_EVENTS + 1);
        assert_eq!(t.events().last().unwrap().kind, TraceKind::Retire);
        assert!(t.dropped() > 0);
        assert!(t.jsonl().contains("\"dropped\""));
    }
}
