//! Named metric registry: atomic counters, gauges, and histograms.
//!
//! Registration (get-or-create by name + label set) takes a Mutex once
//! per series at startup; the returned `Arc` handles are then recorded
//! through with plain atomics — the registry lock is never touched on
//! the hot path. `with_registration_locked` makes that claim testable:
//! it runs a closure while the registry's only lock is held, so any
//! recording call that secretly needed it would self-deadlock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::histogram::{HistSnapshot, Histogram};
use crate::util::sync::lock_recover;

/// Monotone atomic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins atomic gauge (u64 value space; `u64::MAX` is used by
/// callers as an "unbounded" sentinel where that semantic exists).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn sub(&self, n: u64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// The live handle a series points at.
#[derive(Clone, Debug)]
pub enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Metric {
    pub fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

/// One registered time series: a metric name, a (possibly empty) label
/// set, and the live handle.
#[derive(Clone, Debug)]
pub struct Series {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub metric: Metric,
}

/// Point-in-time value of a series, for export.
#[derive(Clone, Debug)]
pub enum SnapValue {
    Counter(u64),
    Gauge(u64),
    Histogram(HistSnapshot),
}

/// Snapshot of one series.
#[derive(Clone, Debug)]
pub struct SeriesSnapshot {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: SnapValue,
}

/// A registry of named series. Cheap to share (`Arc<Registry>`); one
/// per engine/group, plus one server-level registry on the router.
#[derive(Debug, Default)]
pub struct Registry {
    series: Mutex<Vec<Series>>,
}

fn labels_of(labels: &[(&str, &str)]) -> Vec<(String, String)> {
    labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-register a counter under `name` + `labels`. Repeated calls
    /// with the same identity return the same handle.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        let labels = labels_of(labels);
        let mut series = lock_recover(&self.series);
        for s in series.iter() {
            if s.name == name && s.labels == labels {
                if let Metric::Counter(c) = &s.metric {
                    return c.clone();
                }
            }
        }
        let c = Arc::new(Counter::new());
        series.push(Series {
            name: name.to_string(),
            labels,
            metric: Metric::Counter(c.clone()),
        });
        c
    }

    /// Get-or-register a gauge under `name` + `labels`.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        let labels = labels_of(labels);
        let mut series = lock_recover(&self.series);
        for s in series.iter() {
            if s.name == name && s.labels == labels {
                if let Metric::Gauge(g) = &s.metric {
                    return g.clone();
                }
            }
        }
        let g = Arc::new(Gauge::new());
        series.push(Series { name: name.to_string(), labels, metric: Metric::Gauge(g.clone()) });
        g
    }

    /// Get-or-register a histogram under `name` + `labels`.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Histogram> {
        let labels = labels_of(labels);
        let mut series = lock_recover(&self.series);
        for s in series.iter() {
            if s.name == name && s.labels == labels {
                if let Metric::Histogram(h) = &s.metric {
                    return h.clone();
                }
            }
        }
        let h = Arc::new(Histogram::new());
        series.push(Series {
            name: name.to_string(),
            labels,
            metric: Metric::Histogram(h.clone()),
        });
        h
    }

    /// Snapshot every registered series (export path).
    pub fn snapshot(&self) -> Vec<SeriesSnapshot> {
        let series = lock_recover(&self.series);
        series
            .iter()
            .map(|s| SeriesSnapshot {
                name: s.name.clone(),
                labels: s.labels.clone(),
                value: match &s.metric {
                    Metric::Counter(c) => SnapValue::Counter(c.get()),
                    Metric::Gauge(g) => SnapValue::Gauge(g.get()),
                    Metric::Histogram(h) => SnapValue::Histogram(h.snapshot()),
                },
            })
            .collect()
    }

    /// Test hook: run `f` while the registry's registration lock is
    /// held by this thread. Any metric-recording call inside `f` that
    /// touched this lock would self-deadlock (std Mutex is not
    /// reentrant), so a completing closure proves recording is
    /// registry-lock-free. See `tests/obs.rs`.
    pub fn with_registration_locked(&self, f: impl FnOnce()) {
        let _guard = lock_recover(&self.series);
        f();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_or_register_returns_same_handle() {
        let r = Registry::new();
        let a = r.counter("swan_x_total", &[("outcome", "ok")]);
        let b = r.counter("swan_x_total", &[("outcome", "ok")]);
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        // Different label set is a different series.
        let c = r.counter("swan_x_total", &[("outcome", "err")]);
        assert_eq!(c.get(), 0);
        assert_eq!(r.snapshot().len(), 2);
    }

    #[test]
    fn gauge_set_and_sentinel() {
        let r = Registry::new();
        let g = r.gauge("swan_pool_blocks_target", &[]);
        g.set(u64::MAX);
        assert_eq!(g.get(), u64::MAX);
        g.set(64);
        g.add(3);
        g.sub(1);
        assert_eq!(g.get(), 66);
    }
}
