//! Prometheus text exposition (format 0.0.4) over one or more
//! registries.
//!
//! Fleet merge rules, chosen to match how each metric class is read:
//!
//! * **counters** — summed across sources that registered the same
//!   `(name, labels)` series (a fleet-total `swan_requests_total` is
//!   what a rate() query wants);
//! * **gauges** — emitted per source, with the source's identity label
//!   (e.g. `shard="1"`) injected, since point-in-time values like
//!   `swan_kv_bytes` or `swan_k_active` are meaningless summed across
//!   heterogeneous shards;
//! * **histograms** — bucket-wise merged (exact — see
//!   `Histogram::merge_from`), so fleet `swan_ttft_seconds_bucket{le=..}`
//!   quantiles reflect every request wherever it ran.

use std::collections::BTreeMap;

use super::histogram::{bucket_le_ns, HistSnapshot, N_BUCKETS};
use super::registry::{Registry, SnapValue};

/// One registry to export, with an optional identity label injected
/// into its gauges (`("shard", "0")`); `None` for server-level series.
pub struct Source<'a> {
    pub label: Option<(String, String)>,
    pub registry: &'a Registry,
}

impl<'a> Source<'a> {
    pub fn new(registry: &'a Registry) -> Source<'a> {
        Source { label: None, registry }
    }

    pub fn shard(id: u64, registry: &'a Registry) -> Source<'a> {
        Source { label: Some(("shard".to_string(), id.to_string())), registry }
    }
}

/// Escape a label value per the exposition format.
fn escape(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Render a label set as `{k="v",...}` (empty string if none).
fn label_block(labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape(v))).collect();
    format!("{{{}}}", parts.join(","))
}

/// Nanoseconds → seconds, rendered as a plain decimal float (Rust's
/// f64 Display never uses exponent notation, so every value parses).
fn secs(ns: u64) -> String {
    format!("{}", ns as f64 / 1e9)
}

enum Merged {
    Counter(u64),
    Gauge(u64),
    Histogram(HistSnapshot),
}

impl Merged {
    fn kind(&self) -> &'static str {
        match self {
            Merged::Counter(_) => "counter",
            Merged::Gauge(_) => "gauge",
            Merged::Histogram(_) => "histogram",
        }
    }
}

/// Render the fleet exposition over `sources`. Series are grouped by
/// metric name (one `# TYPE` line each), merged per the module rules,
/// and emitted in sorted order so output is stable for golden tests.
pub fn render(sources: &[Source]) -> String {
    // name -> (kind, label-block -> merged value)
    let mut families: BTreeMap<String, (&'static str, BTreeMap<String, Merged>)> = BTreeMap::new();
    for src in sources {
        for s in src.registry.snapshot() {
            let mut labels = s.labels.clone();
            if let SnapValue::Gauge(_) = s.value {
                if let Some((k, v)) = &src.label {
                    labels.push((k.clone(), v.clone()));
                }
            }
            labels.sort();
            let key = label_block(&labels);
            let new = match s.value {
                SnapValue::Counter(v) => Merged::Counter(v),
                SnapValue::Gauge(v) => Merged::Gauge(v),
                SnapValue::Histogram(h) => Merged::Histogram(h),
            };
            let fam =
                families.entry(s.name.clone()).or_insert_with(|| (new.kind(), BTreeMap::new()));
            if fam.0 != new.kind() {
                // Kind conflict across sources: first registration wins;
                // the mismatched series is dropped rather than emitting
                // an invalid exposition.
                debug_assert!(
                    false,
                    "metric {} registered as {} and {}",
                    s.name,
                    fam.0,
                    new.kind()
                );
                continue;
            }
            match fam.1.entry(key) {
                std::collections::btree_map::Entry::Vacant(e) => {
                    e.insert(new);
                }
                std::collections::btree_map::Entry::Occupied(mut e) => match (e.get_mut(), new) {
                    (Merged::Counter(a), Merged::Counter(b)) => *a += b,
                    (Merged::Histogram(a), Merged::Histogram(b)) => a.merge(&b),
                    // Gauges carry per-source labels, so a key collision
                    // means two identically-labeled sources: last wins.
                    (Merged::Gauge(a), Merged::Gauge(b)) => *a = b,
                    _ => {}
                },
            }
        }
    }

    let mut out = String::new();
    for (name, (kind, series)) in &families {
        out.push_str(&format!("# TYPE {name} {kind}\n"));
        for (key, value) in series {
            match value {
                Merged::Counter(v) | Merged::Gauge(v) => {
                    out.push_str(&format!("{name}{key} {v}\n"));
                }
                Merged::Histogram(h) => render_histogram(&mut out, name, key, h),
            }
        }
    }
    out
}

/// Emit cumulative `_bucket{le=...}` lines plus `_sum` / `_count`,
/// with `le` bounds converted from ns to seconds.
fn render_histogram(out: &mut String, name: &str, key: &str, h: &HistSnapshot) {
    // Splice `le` into an existing label block or open a fresh one.
    let with_le = |le: &str| {
        if key.is_empty() {
            format!("{{le=\"{le}\"}}")
        } else {
            format!("{},le=\"{le}\"}}", &key[..key.len() - 1])
        }
    };
    let mut cum = 0u64;
    for (i, &n) in h.buckets.iter().enumerate().take(N_BUCKETS - 1) {
        cum += n;
        let le = secs(bucket_le_ns(i).expect("non-overflow bucket has a bound"));
        out.push_str(&format!("{name}_bucket{} {cum}\n", with_le(&le)));
    }
    cum += h.buckets[N_BUCKETS - 1];
    out.push_str(&format!("{name}_bucket{} {cum}\n", with_le("+Inf")));
    out.push_str(&format!("{name}_sum{key} {}\n", secs(h.sum)));
    out.push_str(&format!("{name}_count{key} {cum}\n"));
}

/// Convenience: render one registry with no identity label.
pub fn render_one(registry: &Registry) -> String {
    render(&[Source::new(registry)])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_sum_and_gauges_split_across_sources() {
        let (a, b) = (Registry::new(), Registry::new());
        a.counter("swan_requests_total", &[("outcome", "completed")]).add(3);
        b.counter("swan_requests_total", &[("outcome", "completed")]).add(4);
        a.gauge("swan_k_active", &[]).set(8);
        b.gauge("swan_k_active", &[]).set(4);
        let text = render(&[Source::shard(0, &a), Source::shard(1, &b)]);
        assert!(text.contains("swan_requests_total{outcome=\"completed\"} 7\n"), "{text}");
        assert!(text.contains("swan_k_active{shard=\"0\"} 8\n"), "{text}");
        assert!(text.contains("swan_k_active{shard=\"1\"} 4\n"), "{text}");
        assert!(text.contains("# TYPE swan_k_active gauge\n"));
    }

    #[test]
    fn histogram_lines_are_cumulative_with_inf() {
        let r = Registry::new();
        let h = r.histogram("swan_ttft_seconds", &[]);
        h.record_ns(1_000);
        h.record_ns(2_000_000);
        let text = render_one(&r);
        assert!(text.contains("# TYPE swan_ttft_seconds histogram\n"));
        assert!(text.contains("swan_ttft_seconds_bucket{le=\"+Inf\"} 2\n"), "{text}");
        assert!(text.contains("swan_ttft_seconds_count 2\n"));
        assert!(text.contains("swan_ttft_seconds_sum 0.002001\n"), "{text}");
    }
}
