//! Lock-free fixed-bucket log2 histogram.
//!
//! The decode hot path records one sample per committed token, so the
//! recording primitive must be wait-free: `record_ns` is exactly two
//! `fetch_add(Relaxed)` operations on pre-sized atomic buckets — no
//! Mutex, no allocation, no branch on contention. Bucket `i` covers the
//! half-open power-of-two range `(2^(i-1), 2^i]` nanoseconds (bucket 0
//! holds `0..=1`), which gives ~2x relative-error quantiles over twelve
//! decades — from 1 ns to ~9 minutes — in 40 u64 slots. The last bucket
//! is the overflow (`+Inf`) bucket.
//!
//! Fleet aggregation is bucket-wise addition (`merge_from`), which is
//! exact: merging N shard histograms is indistinguishable from having
//! recorded every sample into one histogram (associativity is locked by
//! `tests/obs.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Bucket count: upper bounds `2^0 .. 2^38` ns plus one overflow bucket.
/// `2^38` ns is ~275 s, comfortably above any per-request latency here.
pub const N_BUCKETS: usize = 40;

/// Index of the bucket a value lands in: the bit length of `v - 1`,
/// clamped to the overflow bucket. This places `v` in the first bucket
/// whose upper bound `2^i` satisfies `v <= 2^i`.
#[inline]
pub fn bucket_for(v: u64) -> usize {
    let bits = (64 - v.saturating_sub(1).leading_zeros()) as usize;
    bits.min(N_BUCKETS - 1)
}

/// Upper bound (inclusive) of bucket `i` in nanoseconds; `None` for the
/// overflow bucket.
#[inline]
pub fn bucket_le_ns(i: usize) -> Option<u64> {
    if i + 1 < N_BUCKETS {
        Some(1u64 << i)
    } else {
        None
    }
}

/// A lock-free log2-bucketed histogram of nanosecond (or unitless)
/// samples. All methods take `&self`; recording never blocks.
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { buckets: std::array::from_fn(|_| AtomicU64::new(0)), sum: AtomicU64::new(0) }
    }

    /// Record one sample. Two relaxed `fetch_add`s — safe on the
    /// per-token decode path.
    #[inline]
    pub fn record_ns(&self, ns: u64) {
        self.buckets[bucket_for(ns)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(ns, Ordering::Relaxed);
    }

    /// Record a wall-clock duration (saturating at `u64::MAX` ns).
    #[inline]
    pub fn record(&self, d: Duration) {
        self.record_ns(d.as_nanos().min(u64::MAX as u128) as u64);
    }

    /// Record a unitless value (counts, tokens, blocks) into the same
    /// log2 buckets; exported quantiles then read as values, not time.
    #[inline]
    pub fn record_value(&self, v: u64) {
        self.record_ns(v);
    }

    /// Total samples recorded (sum over buckets). Export-path only.
    pub fn count(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    /// Sum of all recorded values, in the recorded unit.
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Bucket-wise add `other` into `self` (fleet merge). Exact: the
    /// result equals recording both sample streams into one histogram.
    pub fn merge_from(&self, other: &Histogram) {
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            let n = theirs.load(Ordering::Relaxed);
            if n > 0 {
                mine.fetch_add(n, Ordering::Relaxed);
            }
        }
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Consistent-enough point-in-time copy for export and quantiles.
    /// (Concurrent recording may skew `sum` vs buckets by in-flight
    /// samples; fine for monitoring.)
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }

    /// Approximate quantile in nanoseconds (see `HistSnapshot::quantile_ns`).
    pub fn quantile_ns(&self, q: f64) -> f64 {
        self.snapshot().quantile_ns(q)
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.snapshot();
        write!(f, "Histogram {{ count: {}, sum: {} }}", s.count(), s.sum)
    }
}

/// Plain-data snapshot of a [`Histogram`]: the export surface.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; N_BUCKETS],
    pub sum: u64,
}

impl HistSnapshot {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Bucket-wise add (fleet merge on snapshots).
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (mine, theirs) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *mine += theirs;
        }
        self.sum += other.sum;
    }

    /// Approximate quantile `q` in [0, 1], linearly interpolated within
    /// the bucket holding the target rank. Relative error is bounded by
    /// the 2x bucket width. Returns 0.0 for an empty histogram.
    pub fn quantile_ns(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let rank = q.clamp(0.0, 1.0) * total as f64;
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let next = seen + n;
            if (next as f64) >= rank {
                let lo = if i == 0 { 0.0 } else { (1u64 << (i - 1)) as f64 };
                let hi = match bucket_le_ns(i) {
                    Some(le) => le as f64,
                    // Overflow bucket: no upper bound; report its floor.
                    None => return lo,
                };
                let frac = ((rank - seen as f64) / n as f64).clamp(0.0, 1.0);
                return lo + (hi - lo) * frac;
            }
            seen = next;
        }
        // Unreachable in practice (rank <= total); report the top bound.
        (1u64 << (N_BUCKETS - 2)) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_bounds_are_half_open_powers_of_two() {
        assert_eq!(bucket_for(0), 0);
        assert_eq!(bucket_for(1), 0);
        assert_eq!(bucket_for(2), 1);
        assert_eq!(bucket_for(3), 2);
        assert_eq!(bucket_for(4), 2);
        assert_eq!(bucket_for(5), 3);
        assert_eq!(bucket_for(1 << 20), 20);
        assert_eq!(bucket_for((1 << 20) + 1), 21);
        assert_eq!(bucket_for(u64::MAX), N_BUCKETS - 1);
        // Every value lands in a bucket whose le bound covers it.
        for v in [0u64, 1, 7, 1000, 123_456_789] {
            let le = bucket_le_ns(bucket_for(v)).unwrap();
            assert!(v <= le, "{v} > le {le}");
            if v > 1 {
                assert!(v > le / 2, "{v} not in ({}, {le}]", le / 2);
            }
        }
    }

    #[test]
    fn record_count_sum_quantile() {
        let h = Histogram::new();
        for v in [10u64, 20, 30, 40, 1000] {
            h.record_ns(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1100);
        let p50 = h.quantile_ns(0.5);
        // Median sample is 30, bucket (16, 32]: interpolation stays in range.
        assert!(p50 > 16.0 && p50 <= 32.0, "p50 = {p50}");
        let p100 = h.quantile_ns(1.0);
        assert!(p100 > 512.0 && p100 <= 1024.0, "p100 = {p100}");
        assert_eq!(Histogram::new().quantile_ns(0.5), 0.0);
    }

    #[test]
    fn merge_is_exact() {
        let (a, b, one) = (Histogram::new(), Histogram::new(), Histogram::new());
        for v in 1..100u64 {
            let target = if v % 2 == 0 { &a } else { &b };
            target.record_ns(v * 17);
            one.record_ns(v * 17);
        }
        a.merge_from(&b);
        assert_eq!(a.snapshot(), one.snapshot());
    }
}
