//! `swan::obs` — dependency-free observability for the serving fleet.
//!
//! Three pieces, threaded through every serving layer:
//!
//! 1. **Registry** ([`registry`]) — named atomic [`Counter`]s /
//!    [`Gauge`]s plus lock-free log2 [`Histogram`]s ([`histogram`]).
//!    Registration locks once per series at startup; recording is pure
//!    relaxed atomics, so nothing here may stall the per-token decode
//!    loop. Per-shard/per-stage dimensions are label sets
//!    (`{stage="1"}`), and fleet aggregation is exact bucket-wise merge.
//! 2. **Tracing** ([`trace`]) — each request carries a [`Trace`] that
//!    timestamps submit → admit → prefill → first token → every decode
//!    commit → preempt/resume → retire. Retired traces land in a
//!    bounded per-engine [`TraceRing`]; the `TRACE <id>` wire verb dumps
//!    one as a JSONL timeline.
//! 3. **Export** ([`export`]) — the `METRICS` wire verb renders all
//!    registries as Prometheus text exposition; `STATS` reads the same
//!    handles (see `coordinator::metrics`), so the two surfaces cannot
//!    disagree.

pub mod export;
pub mod histogram;
pub mod registry;
pub mod trace;

pub use export::{render, render_one, Source};
pub use histogram::{HistSnapshot, Histogram, N_BUCKETS};
pub use registry::{Counter, Gauge, Registry};
pub use trace::{Trace, TraceKind, TraceRing, TRACE_RING_CAP};
