//! `swan` — CLI entrypoint for the SWAN serving stack.

// config builders assign field-by-field over Default on purpose (mirrors
// the flag list); keep clippy's -D warnings CI gate green
#![allow(clippy::field_reassign_with_default)]

use swan::cli::{Args, USAGE};
use swan::config::ServeConfig;
use swan::coordinator::Engine;
use swan::sparse::StorageMode;

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn parse_mode(args: &Args) -> anyhow::Result<StorageMode> {
    match args.get("mode").unwrap_or("16") {
        "16" => Ok(StorageMode::F16),
        "8" => Ok(StorageMode::F8),
        other => anyhow::bail!("--mode must be 16 or 8, got '{other}'"),
    }
}

fn serve_config(args: &Args) -> anyhow::Result<ServeConfig> {
    let mut cfg = ServeConfig::default();
    cfg.model = args.get_str("model", &cfg.model);
    cfg.k_active = args.get_usize("k-active", cfg.k_active)?;
    cfg.buffer = args.get_usize("buffer", cfg.buffer)?;
    cfg.max_batch = args.get_usize("max-batch", cfg.max_batch)?;
    cfg.max_new_tokens = args.get_usize("max-new", cfg.max_new_tokens)?;
    cfg.mem_budget = args.get_usize("mem-budget", cfg.mem_budget)?;
    cfg.decode_workers = args.get_usize("decode-workers", cfg.decode_workers)?;
    cfg.admit_lookahead = args.get_usize("admit-lookahead", cfg.admit_lookahead)?.max(1);
    cfg.shards = args.get_usize("shards", cfg.shards)?;
    anyhow::ensure!(cfg.shards >= 1, "--shards must be >= 1");
    cfg.pipeline = args.get_usize("pipeline", cfg.pipeline)?;
    anyhow::ensure!(cfg.pipeline >= 1, "--pipeline must be >= 1");
    anyhow::ensure!(
        cfg.shards % cfg.pipeline == 0,
        "--shards ({}) must be a multiple of --pipeline ({}) so stages form whole groups",
        cfg.shards,
        cfg.pipeline
    );
    cfg.balance = args.get_str("balance", &cfg.balance);
    // fail fast on a typo'd policy name (the router re-validates at launch)
    swan::shard::balance::policy_from_name(&cfg.balance)?;
    cfg.kernels = args.get_str("kernels", &cfg.kernels);
    cfg.mode = parse_mode(args)?;
    cfg.dense_baseline = args.has("dense");
    cfg.pool = args.has("pool");
    cfg.block_tokens = args.get_usize("block-tokens", cfg.block_tokens)?;
    anyhow::ensure!(cfg.block_tokens >= 1, "--block-tokens must be >= 1");
    cfg.drain_timeout_ms =
        args.get_usize("drain-timeout", cfg.drain_timeout_ms as usize)? as u64;
    cfg.prefix = args.has("prefix-cache");
    anyhow::ensure!(
        !(cfg.pool && cfg.dense_baseline),
        "--pool serves SWAN hybrid caches; it cannot combine with --dense"
    );
    anyhow::ensure!(
        !(cfg.prefix && cfg.dense_baseline),
        "--prefix-cache reuses SWAN winnowed blocks; it cannot combine with --dense"
    );
    cfg.bind = args.get_str("bind", &cfg.bind);
    Ok(cfg)
}

fn run(args: &Args) -> anyhow::Result<()> {
    // pin the compute kernel path before anything dispatches (applies to
    // every command; `auto` picks the best the host supports)
    let kernels = swan::simd::init_from_name(args.get("kernels").unwrap_or("auto"))?;
    log::debug!("kernels: {}", kernels.label());
    let artifacts = swan::artifacts_dir();
    match args.command.as_str() {
        "serve" => {
            let cfg = serve_config(args)?;
            swan::server::serve(&artifacts, cfg)
        }
        "generate" => {
            anyhow::ensure!(!args.positional.is_empty(), "generate: missing prompt");
            let prompt = args.positional.join(" ");
            let cfg = serve_config(args)?;
            let mut engine = Engine::new(&artifacts, cfg)?;
            let max_new = args.get_usize("max-new", 48)?;
            let mut params = swan::api::GenParams::new(max_new)
                .temperature(args.get_f32("temperature", 0.0)?)
                .top_p(args.get_f32("top-p", 1.0)?)
                .repetition_penalty(args.get_f32("rep-penalty", 1.0)?)
                .stream(args.has("stream"));
            if let Some(seed) = args.get_opt_u64("seed")? {
                params = params.seed(seed);
            }
            if let Some(k) = args.get_opt_u64("k")? {
                // per-request compression override (snapped to a
                // compiled bucket at admission)
                params = params.k_active(k as usize);
            }
            let streaming = params.stream;
            let handle =
                engine.submit_handle(swan::coordinator::Request::with_params(0, &prompt, params));
            // drive the engine on this thread; drain events as they land
            let resp = loop {
                engine.step()?;
                let mut done = None;
                while let Some(ev) = handle.try_recv() {
                    match ev {
                        swan::api::Event::Token { text, .. } => {
                            if streaming {
                                print!("{text}");
                                use std::io::Write;
                                let _ = std::io::stdout().flush();
                            }
                        }
                        swan::api::Event::Done(r) => done = Some(r),
                        swan::api::Event::Error { message, .. } => {
                            anyhow::bail!("generation failed: {message}")
                        }
                    }
                }
                if let Some(r) = done {
                    break r;
                }
                anyhow::ensure!(engine.has_work(), "engine idle before the generation finished");
            };
            if streaming {
                println!();
            } else {
                println!("{}", resp.text);
            }
            let r = resp;
            println!(
                "[prefill {:.1} ms | {} tokens in {:.1} ms = {:.1} tok/s | kv saving {:.1}%]",
                r.stats.prefill_time.as_secs_f64() * 1e3,
                r.stats.decode_steps,
                r.stats.decode_time.as_secs_f64() * 1e3,
                r.stats.decode_tps(),
                r.stats.memory_saving() * 100.0
            );
            Ok(())
        }
        "eval" => {
            let cases = args.get_usize("cases", 10)?;
            let model_name = args.get_str("model", "swan-nano-gqa");
            let mut ctx = swan::repro::ReproCtx::new(artifacts, cases);
            let model = ctx.model(&model_name)?;
            let mut h = swan::eval::Harness::new(model);
            let mut rows = Vec::new();
            for t in &swan::eval::tasks::standard_battery(cases, 5) {
                rows.push(h.run_task(t, swan::kvcache::PolicyKind::Dense));
                rows.push(h.run_task(
                    t,
                    swan::kvcache::PolicyKind::Swan {
                        k_active: 32,
                        buffer: 64,
                        mode: StorageMode::F16,
                    },
                ));
            }
            print!("{}", swan::eval::harness::format_table(&model_name, &rows));
            Ok(())
        }
        "repro" => {
            anyhow::ensure!(!args.positional.is_empty(), "repro: missing experiment name");
            let cases = args.get_usize("cases", 10)?;
            let mut ctx = swan::repro::ReproCtx::new(artifacts, cases);
            let names: Vec<&str> = if args.positional[0] == "all" {
                swan::repro::ALL.to_vec()
            } else {
                args.positional.iter().map(String::as_str).collect()
            };
            for name in names {
                eprintln!(">>> running {name} ...");
                let out = swan::repro::run(name, &mut ctx)?;
                println!("{out}");
            }
            Ok(())
        }
        "breakeven" => {
            let d = args.get_usize("d-head", 128)?;
            let b = args.get_usize("buffer", 128)?;
            println!("break-even sequence lengths (d_h={d}, buffer={b}):");
            println!("{:<10} {:>12}", "k_active", "L*");
            for frac in [0.25f64, 0.5, 0.75, 0.9] {
                let k = (frac * d as f64).round() as usize;
                match swan::swan::breakeven::breakeven_length(d, b, k) {
                    Some(l) => println!("{k:<10} {l:>12.1}"),
                    None => println!("{k:<10} {:>12}", "never"),
                }
            }
            Ok(())
        }
        "info" => {
            let store = swan::runtime::ArtifactStore::load(&artifacts)?;
            println!("artifacts: {}", store.dir.display());
            for (name, m) in &store.models {
                println!(
                    "  {name}: {} layers, {} q / {} kv heads, d_h {}, graphs: {}",
                    m.config.n_layers,
                    m.config.n_q_heads,
                    m.config.n_kv_heads,
                    m.config.d_head,
                    m.graphs.len()
                );
                println!(
                    "    decode buckets {:?}, prefill {:?}",
                    m.decode_buckets(),
                    m.prefill_buckets()
                );
            }
            let rt = swan::runtime::Runtime::new()?;
            println!("pjrt platform: {}", rt.platform());
            Ok(())
        }
        "" | "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}
