//! `swan::api` — the typed request/response layer shared by every serving
//! path (in-process [`crate::coordinator::Engine`], the shard router, the
//! pipeline-group coordinator, and the TCP wire protocol).
//!
//! * [`GenParams`] — builder-style generation parameters.  Beyond the
//!   classic sampling knobs it carries `k_active`, a **per-request
//!   compression override**: SWAN's compression level is runtime-tunable
//!   per sequence (every sequence owns its own winnowed cache), so a
//!   latency-tolerant request can ask for `k=8` while a quality-sensitive
//!   one on the same shard decodes at the fleet default.  Admission
//!   control and `MemAware` placement project KV bytes from the
//!   *request's own* k, not the fleet level.
//! * [`Event`] / [`GenHandle`] — submission returns a handle with a
//!   token-event channel: [`Event::Token`] per decoded token (when
//!   `stream` is set), then exactly one terminal [`Event::Done`] or
//!   [`Event::Error`].
//! * [`CancelToken`] — cooperative cancellation.  `GenHandle::cancel`
//!   (or the wire `CANCEL <id>`) flips the flag; the owning engine or
//!   pipeline group retires the sequence at its next decode iteration,
//!   answering the handle with a partial [`Response`]
//!   (`stats.cancelled = true`) and never disturbing co-batched
//!   sequences.
//!
//! The handle survives fleet churn: when the shard serving a request
//! dies or drains, the supervisor re-places the request — carrying this
//! same event channel — on a healthy shard, which re-prefills and
//! replays the committed tokens as forced steps.  SWAN decode is
//! deterministic, so the stream resumes bit-identically (no gap, no
//! duplicate, same tokens); the caller observes at most a latency blip.
//! Only when no healthy shard remains does the handle receive a terminal
//! [`Event::Error`] with a `shard_lost:` message.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};

use crate::coordinator::request::Response;

/// Typed generation parameters (the v2 replacement for the loose
/// `max_new_tokens` / `temperature` / `stop_token` fields the request
/// struct used to carry).  Build with the fluent setters:
///
/// ```ignore
/// let p = GenParams::new(64).temperature(0.8).top_p(0.9).k_active(8).stream(true);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct GenParams {
    /// Max new tokens to decode (servers may clamp; the clamp is
    /// surfaced in [`crate::coordinator::request::RequestStats`], never
    /// silent).
    pub max_new: usize,
    /// Softmax temperature; `<= 0` = greedy.
    pub temperature: f32,
    /// Nucleus sampling mass; `>= 1.0` disables (sample the full
    /// distribution).  Only meaningful with `temperature > 0`.
    pub top_p: f32,
    /// CTRL-style repetition penalty over already-generated tokens;
    /// `1.0` disables.
    pub repetition_penalty: f32,
    /// RNG stream seed override; `None` derives the stream from the
    /// request id (the historical default, so legacy requests keep their
    /// exact token streams).
    pub seed: Option<u64>,
    /// Optional stop token id.
    pub stop: Option<u32>,
    /// Per-request compression override: `Some(k)` admits this sequence
    /// at compression level `k` (snapped to a compiled bucket on the
    /// PJRT path, clamped to `d_head` on the native path) regardless of
    /// the fleet-wide `k_active`.
    pub k_active: Option<usize>,
    /// Emit [`Event::Token`] per decoded token (otherwise only the
    /// terminal event is sent).
    pub stream: bool,
}

impl Default for GenParams {
    fn default() -> GenParams {
        GenParams {
            max_new: 64,
            temperature: 0.0,
            top_p: 1.0,
            repetition_penalty: 1.0,
            seed: None,
            stop: None,
            k_active: None,
            stream: false,
        }
    }
}

impl GenParams {
    pub fn new(max_new: usize) -> GenParams {
        GenParams { max_new, ..Default::default() }
    }

    pub fn temperature(mut self, t: f32) -> Self {
        self.temperature = t;
        self
    }

    pub fn top_p(mut self, p: f32) -> Self {
        self.top_p = p;
        self
    }

    pub fn repetition_penalty(mut self, p: f32) -> Self {
        self.repetition_penalty = p;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = Some(s);
        self
    }

    pub fn stop(mut self, tok: u32) -> Self {
        self.stop = Some(tok);
        self
    }

    pub fn k_active(mut self, k: usize) -> Self {
        self.k_active = Some(k);
        self
    }

    pub fn stream(mut self, on: bool) -> Self {
        self.stream = on;
        self
    }
}

/// Shared cooperative-cancellation flag.  Clones observe the same flag;
/// flipping it is idempotent and thread-safe.  The serving loops poll it
/// once per decode iteration (and at admission), so a cancelled sequence
/// retires within one iteration.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }
}

/// One event on a generation's channel.  A generation emits zero or more
/// `Token`s (only with `GenParams::stream`) followed by exactly one
/// terminal `Done` or `Error`.
#[derive(Clone, Debug)]
pub enum Event {
    /// One decoded token, in order.  `index` counts from 0 (the token
    /// sampled from the prefill logits).
    Token { id: u64, index: usize, token: u32, text: String },
    /// The generation finished (including cancelled generations, which
    /// carry their partial output and `stats.cancelled = true`).
    Done(Response),
    /// The generation failed (admission rejection, engine failure);
    /// no `Done` follows.
    Error { id: u64, message: String },
}

impl Event {
    /// The request this event belongs to.
    pub fn id(&self) -> u64 {
        match self {
            Event::Token { id, .. } | Event::Error { id, .. } => *id,
            Event::Done(r) => r.id,
        }
    }
}

/// The caller's side of one submitted generation: the event channel plus
/// the cancellation token.  Obtained from `Router::submit` or
/// `Engine::submit_handle`.
pub struct GenHandle {
    id: u64,
    rx: mpsc::Receiver<Event>,
    cancel: CancelToken,
}

impl GenHandle {
    /// Pair a handle with the event sender its engine will feed.
    pub fn channel(id: u64, cancel: CancelToken) -> (mpsc::Sender<Event>, GenHandle) {
        let (tx, rx) = mpsc::channel();
        (tx, GenHandle { id, rx, cancel })
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    /// Request cancellation; the sequence retires at its owner's next
    /// decode iteration and the channel still delivers a terminal event.
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// A clone of the cancellation token (e.g. for a connection-level
    /// registry that outlives the handle).
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// Block for the next event.
    pub fn recv(&self) -> anyhow::Result<Event> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("generation {}: engine gone", self.id))
    }

    /// Non-blocking poll (for in-process callers driving the engine on
    /// the same thread).
    pub fn try_recv(&self) -> Option<Event> {
        self.rx.try_recv().ok()
    }

    /// Drain the channel to the terminal event and return the response
    /// (token events, if any, are discarded).
    pub fn wait(self) -> anyhow::Result<Response> {
        loop {
            match self.recv()? {
                Event::Token { .. } => continue,
                Event::Done(resp) => return Ok(resp),
                Event::Error { id, message } => {
                    anyhow::bail!("generation {id} failed: {message}")
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::RequestStats;

    #[test]
    fn builder_sets_fields_over_defaults() {
        let p = GenParams::new(32)
            .temperature(0.7)
            .top_p(0.9)
            .repetition_penalty(1.2)
            .seed(42)
            .stop(5)
            .k_active(8)
            .stream(true);
        assert_eq!(p.max_new, 32);
        assert_eq!(p.temperature, 0.7);
        assert_eq!(p.top_p, 0.9);
        assert_eq!(p.repetition_penalty, 1.2);
        assert_eq!(p.seed, Some(42));
        assert_eq!(p.stop, Some(5));
        assert_eq!(p.k_active, Some(8));
        assert!(p.stream);
        let d = GenParams::default();
        assert_eq!(d.top_p, 1.0);
        assert_eq!(d.repetition_penalty, 1.0);
        assert!(!d.stream);
    }

    #[test]
    fn cancel_token_is_shared_across_clones() {
        let t = CancelToken::new();
        let c = t.clone();
        assert!(!c.is_cancelled());
        t.cancel();
        assert!(c.is_cancelled());
    }

    #[test]
    fn handle_streams_tokens_then_done() {
        let (tx, handle) = GenHandle::channel(7, CancelToken::new());
        tx.send(Event::Token { id: 7, index: 0, token: 1, text: "a".into() }).unwrap();
        tx.send(Event::Done(Response {
            id: 7,
            tokens: vec![1],
            text: "a".into(),
            stats: RequestStats::default(),
        }))
        .unwrap();
        assert_eq!(handle.id(), 7);
        match handle.recv().unwrap() {
            Event::Token { index: 0, token: 1, .. } => {}
            other => panic!("expected first token, got {other:?}"),
        }
        let resp = handle.wait().unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.text, "a");
    }

    #[test]
    fn wait_surfaces_errors() {
        let (tx, handle) = GenHandle::channel(3, CancelToken::new());
        tx.send(Event::Error { id: 3, message: "rejected".into() }).unwrap();
        let err = handle.wait().unwrap_err().to_string();
        assert!(err.contains("rejected"), "{err}");
    }

    #[test]
    fn dropped_sender_is_engine_gone() {
        let (tx, handle) = GenHandle::channel(9, CancelToken::new());
        drop(tx);
        assert!(handle.recv().unwrap_err().to_string().contains("engine gone"));
    }

    #[test]
    fn handle_cancel_flips_the_shared_token() {
        let (_tx, handle) = GenHandle::channel(1, CancelToken::new());
        let tok = handle.cancel_token();
        handle.cancel();
        assert!(tok.is_cancelled());
    }
}
