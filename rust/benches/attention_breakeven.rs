//! Bench: Eq. 2 computational break-even (paper §5.2 / Appendix A.2.1).
//!
//! Measures the rust decompression-free attention against dense attention
//! over a sequence-length sweep and reports where the measured crossover
//! falls relative to the closed-form prediction.  (In-repo harness;
//! criterion is unavailable offline.)

use swan::sparse::StorageMode;
use swan::swan::attention::{dense_attention, swan_attention};
use swan::swan::breakeven::breakeven_length;
use swan::swan::hybrid_cache::{HybridCache, SwanParams};
use swan::tensor::ops::vecmat;
use swan::util::stats::{bench_batched, Summary};
use swan::util::Pcg64;

fn main() {
    let d = 128usize;
    let b = 128usize;
    println!("# attention_breakeven (d_h={d}, buffer={b})");
    println!(
        "{:<8} {:<10} {:>14} {:>14} {:>8}",
        "L", "k_active", "dense median", "swan median", "ratio"
    );
    let mut rng = Pcg64::new(7);
    let q = rng.normal_vec(d);
    let kc = rng.normal_vec(d);
    let vc = rng.normal_vec(d);
    let proj = rng.normal_vec(d * d);

    for &k_active in &[32usize, 64, 96] {
        let mut crossover = None;
        for &l in &[64usize, 128, 256, 512, 1024, 2048, 4096, 8192] {
            let kflat = rng.normal_vec(l * d);
            let vflat = rng.normal_vec(l * d);
            let mut out = vec![0.0f32; d];
            let dense_t = bench_batched(3, 12, 4, || {
                dense_attention(&q, &kflat, &vflat, &kc, &vc, d, &mut out);
                std::hint::black_box(&out);
            });
            let mut cache = HybridCache::new(d, SwanParams::new(k_active, b.min(l), StorageMode::F32));
            for t in 0..l {
                cache.append(&kflat[t * d..(t + 1) * d], &vflat[t * d..(t + 1) * d]);
            }
            let mut qr = vec![0.0f32; d];
            let mut kr = vec![0.0f32; d];
            let swan_t = bench_batched(3, 12, 4, || {
                vecmat(&q, &proj, d, d, &mut qr);
                vecmat(&kc, &proj, d, d, &mut kr);
                swan_attention(&qr, &cache, &kr, &vc, &mut out);
                std::hint::black_box(&out);
            });
            let ratio = swan_t.median_ns / dense_t.median_ns;
            if ratio < 1.0 && crossover.is_none() {
                crossover = Some(l);
            }
            println!(
                "{l:<8} {k_active:<10} {:>14} {:>14} {ratio:>8.3}",
                Summary::fmt_time(dense_t.median_ns),
                Summary::fmt_time(swan_t.median_ns)
            );
        }
        let formula = breakeven_length(d, b, k_active).unwrap();
        println!(
            "k={k_active}: measured crossover {} | formula L* = {formula:.0}\n",
            crossover.map(|l| l.to_string()).unwrap_or_else(|| "not reached".into())
        );
    }
}
