//! Bench: per-step attend+append cost of every cache policy at a fixed
//! history length — the compute side of the related-work comparison
//! (KIVI pays an explicit dequantization pass; SWAN does not).

use swan::kvcache::{PolicyKind, CachePolicy};
use swan::sparse::StorageMode;
use swan::util::stats::{bench, Summary};
use swan::util::Pcg64;

fn main() {
    let d = 128usize;
    let hist = 1024usize;
    println!("# cache_policies (d_h={d}, history={hist} tokens): attend cost/step");
    let kinds = [
        PolicyKind::Dense,
        PolicyKind::Swan { k_active: 32, buffer: 128, mode: StorageMode::F16 },
        PolicyKind::Swan { k_active: 64, buffer: 128, mode: StorageMode::F16 },
        PolicyKind::Swan { k_active: 32, buffer: 128, mode: StorageMode::F8 },
        PolicyKind::H2O { budget: 512, recent: 128 },
        PolicyKind::Streaming { sinks: 4, window: 508 },
        PolicyKind::Kivi { bits: 4, residual: 128 },
        PolicyKind::Kivi { bits: 8, residual: 128 },
    ];
    let mut rng = Pcg64::new(1);
    let stream: Vec<(Vec<f32>, Vec<f32>)> =
        (0..hist).map(|_| (rng.normal_vec(d), rng.normal_vec(d))).collect();
    let q = rng.normal_vec(d);
    let kc = rng.normal_vec(d);
    let vc = rng.normal_vec(d);

    for kind in kinds {
        let mut p: Box<dyn CachePolicy> = kind.build(d);
        for (k, v) in &stream {
            p.append(k, v);
        }
        let mut out = vec![0.0f32; d];
        let t = bench(3, 25, || {
            p.attend(&q, &kc, &vc, &mut out);
            std::hint::black_box(&out);
        });
        println!(
            "{:<36} {:>12}   mem {:>10} ({} tokens retained)",
            kind.label(),
            Summary::fmt_time(t.median_ns),
            swan::sparse::memory::human_bytes(p.storage_bytes()),
            p.retained_tokens()
        );
    }
}
