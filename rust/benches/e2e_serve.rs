//! Bench: end-to-end serving throughput — batched requests through the
//! full coordinator (prefill graph + hybrid-cache decode + continuous
//! batching), SWAN vs the dense-baseline serving mode, shard scaling
//! through the front-end router, plus an `api_mix` section comparing
//! greedy / top-p / repetition-penalty / streaming / per-request-k-mixed
//! batches (written to `BENCH_api.json`).  Reports request latency,
//! decode tok/s and KV memory savings (needs `make artifacts`).

use swan::api::GenParams;
use swan::config::ServeConfig;
use swan::coordinator::{Engine, Request};
use swan::eval::corpus;
use swan::shard::Router;
use swan::sparse::StorageMode;
use swan::util::Pcg64;

fn run_batch(cfg: ServeConfig, n_requests: usize, max_new: usize) -> anyhow::Result<String> {
    let dir = swan::artifacts_dir();
    let mut engine = Engine::new(&dir, cfg)?;
    engine.warmup()?;
    let mut rng = Pcg64::new(42);
    let t0 = std::time::Instant::now();
    for i in 0..n_requests {
        let prompt = format!(
            "{} the {} ",
            corpus::mixed_text(&mut rng.fork(i as u64), 180),
            corpus::NOUNS[i % corpus::NOUNS.len()]
        );
        engine.submit_text(&prompt, max_new);
    }
    let responses = engine.run_to_completion()?;
    let wall = t0.elapsed();
    let total_decoded: usize = responses.iter().map(|r| r.stats.decode_steps).sum();
    let mean_decode_tps: f64 =
        responses.iter().map(|r| r.stats.decode_tps()).sum::<f64>() / responses.len() as f64;
    let mean_saving: f64 =
        responses.iter().map(|r| r.stats.memory_saving()).sum::<f64>() / responses.len() as f64;
    let mean_prefill_ms: f64 = responses
        .iter()
        .map(|r| r.stats.prefill_time.as_secs_f64() * 1e3)
        .sum::<f64>()
        / responses.len() as f64;
    Ok(format!(
        "requests {:>3} | wall {:>7.2}s | agg decode {:>7.1} tok/s | per-seq {:>7.1} tok/s | \
         prefill {:>6.1} ms | kv saving {:>5.1}%",
        responses.len(),
        wall.as_secs_f64(),
        total_decoded as f64 / wall.as_secs_f64(),
        mean_decode_tps,
        mean_prefill_ms,
        mean_saving * 100.0
    ))
}

/// Drive `n_requests` concurrent generations through an already-built
/// router (engine shards or pipeline groups — the driver is topology-
/// agnostic); returns the aggregate decode tokens/sec and the row.
fn drive_router(router: &Router, n_requests: usize, max_new: usize) -> anyhow::Result<(f64, String)> {
    let mut rng = Pcg64::new(42);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let prompt = format!(
            "{} the {} ",
            corpus::mixed_text(&mut rng.fork(i as u64), 180),
            corpus::NOUNS[i % corpus::NOUNS.len()]
        );
        pending.push(router.submit(Request::from_text(0, &prompt, max_new))?);
    }
    let mut total_decoded = 0usize;
    for handle in pending {
        let resp = handle.wait()?;
        total_decoded += resp.stats.decode_steps;
    }
    let wall = t0.elapsed();
    let tps = total_decoded as f64 / wall.as_secs_f64();
    Ok((
        tps,
        format!(
            "requests {:>3} | wall {:>7.2}s | agg decode {:>7.1} tok/s",
            n_requests,
            wall.as_secs_f64(),
            tps,
        ),
    ))
}

/// Shard-scaling leg: the full `Router::launch` fleet (PJRT engines).
fn run_shard_batch(cfg: ServeConfig, n_requests: usize, max_new: usize) -> anyhow::Result<(f64, String)> {
    let router = Router::launch(&swan::artifacts_dir(), cfg)?;
    drive_router(&router, n_requests, max_new)
}

/// Drive one api-mix scenario: `n` concurrent requests whose params come
/// from `mk(i)`; returns aggregate decode tokens/sec.  Streamed token
/// events, when a scenario enables them, flow through the same handles
/// (`wait` drains them), so the row prices the full event path.
fn drive_params(
    router: &Router,
    n: usize,
    mk: impl Fn(u64) -> GenParams,
) -> anyhow::Result<f64> {
    let mut rng = Pcg64::new(42);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(n);
    for i in 0..n {
        let prompt = format!(
            "{} the {} ",
            corpus::mixed_text(&mut rng.fork(i as u64), 180),
            corpus::NOUNS[i % corpus::NOUNS.len()]
        );
        pending.push(router.submit(Request::with_params(0, &prompt, mk(i as u64)))?);
    }
    let mut decoded = 0usize;
    for h in pending {
        decoded += h.wait()?.stats.decode_steps;
    }
    Ok(decoded as f64 / t0.elapsed().as_secs_f64())
}

/// Pipeline-scaling leg: ONE native pipeline group of `cfg.pipeline`
/// stages, built directly from `pipeline::launch_group` so every row —
/// including the depth-1 baseline — runs the same (native) backend and
/// the sweep varies only stage depth (`Router::launch` would serve
/// pipeline=1 through the PJRT engine instead).
fn run_pipeline_batch(
    cfg: ServeConfig,
    n_requests: usize,
    max_new: usize,
) -> anyhow::Result<(f64, String)> {
    use swan::model::{SwanModel, WeightFile};
    use swan::shard::pipeline::launch_group;
    use swan::swan::projection::ProjectionVariant;

    let dir = swan::artifacts_dir();
    let wf = WeightFile::load(&dir.join(format!("weights_{}.bin", cfg.model)))?;
    let model = std::sync::Arc::new(SwanModel::load(&wf, ProjectionVariant::Calibrated, 0)?);
    let handle = launch_group(0, model, &cfg)?;
    let router = Router::from_handles(vec![handle], swan::shard::policy_from_name("round-robin")?);
    drive_router(&router, n_requests, max_new)
}

/// Pull the `frag=<pct>%` figure out of a fleet stats render (present
/// only while a pool-mode group has live sequences).
fn parse_frag(stats: &str) -> Option<f64> {
    let rest = &stats[stats.find("frag=")? + 5..];
    rest[..rest.find('%')?].parse().ok()
}

/// Pool-mode leg: one native pipeline group serving out of the paged
/// block pool.  Also samples mid-flight fragmentation from STATS and
/// reads the preemption counter after the batch drains.
fn run_pool_batch(
    cfg: ServeConfig,
    n_requests: usize,
    max_new: usize,
) -> anyhow::Result<(f64, Option<f64>, u64, String)> {
    use swan::model::{SwanModel, WeightFile};
    use swan::shard::pipeline::launch_group;
    use swan::swan::projection::ProjectionVariant;

    let dir = swan::artifacts_dir();
    let wf = WeightFile::load(&dir.join(format!("weights_{}.bin", cfg.model)))?;
    let model = std::sync::Arc::new(SwanModel::load(&wf, ProjectionVariant::Calibrated, 0)?);
    let handle = launch_group(0, model, &cfg)?;
    let router = Router::from_handles(vec![handle], swan::shard::policy_from_name("round-robin")?);

    let mut rng = Pcg64::new(42);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let prompt = format!(
            "{} the {} ",
            corpus::mixed_text(&mut rng.fork(i as u64), 180),
            corpus::NOUNS[i % corpus::NOUNS.len()]
        );
        pending.push(router.submit(Request::from_text(0, &prompt, max_new))?);
    }
    // sample fragmentation while the batch is in flight (the pool line
    // renders live rows vs leased-block row capacity)
    std::thread::sleep(std::time::Duration::from_millis(50));
    let frag = parse_frag(&router.stats());
    let mut decoded = 0usize;
    for h in pending {
        decoded += h.wait()?.stats.decode_steps;
    }
    let wall = t0.elapsed();
    let tps = decoded as f64 / wall.as_secs_f64();
    let preempted: u64 = router
        .shards()
        .iter()
        .map(|s| s.metrics.requests_preempted.get())
        .sum();
    let row = format!(
        "requests {:>3} | wall {:>7.2}s | agg decode {:>7.1} tok/s | preempted {preempted}",
        n_requests,
        wall.as_secs_f64(),
        tps,
    );
    Ok((tps, frag, preempted, row))
}

/// Open-loop SLO leg: Poisson request arrivals (exponential interarrival
/// gaps, `dt = -ln(U) * mean`, seeded) against a native pipeline group,
/// so queue wait and TTFT spread the way a live fleet's do — bursts and
/// lulls included, which a fixed stagger never produces.  The
/// percentiles are read from the same lock-free obs histograms the
/// `METRICS` verb exports — no bench-side timing — merged across shards
/// with the exact bucket-wise merge.
fn run_latency_slo(
    cfg: ServeConfig,
    n_requests: usize,
    max_new: usize,
    mean_interarrival: std::time::Duration,
) -> anyhow::Result<(swan::obs::HistSnapshot, swan::obs::HistSnapshot)> {
    use swan::model::{SwanModel, WeightFile};
    use swan::shard::pipeline::launch_group;
    use swan::swan::projection::ProjectionVariant;

    let dir = swan::artifacts_dir();
    let wf = WeightFile::load(&dir.join(format!("weights_{}.bin", cfg.model)))?;
    let model = std::sync::Arc::new(SwanModel::load(&wf, ProjectionVariant::Calibrated, 0)?);
    let handle = launch_group(0, model, &cfg)?;
    let router = Router::from_handles(vec![handle], swan::shard::policy_from_name("round-robin")?);
    let mut rng = Pcg64::new(42);
    let mut arrivals = Pcg64::new(7); // separate stream: prompts stay fixed
    let mut pending = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let prompt = format!(
            "{} the {} ",
            corpus::mixed_text(&mut rng.fork(i as u64), 180),
            corpus::NOUNS[i % corpus::NOUNS.len()]
        );
        pending.push(router.submit(Request::from_text(0, &prompt, max_new))?);
        // exponential gap: U in [0,1) => use 1-U in (0,1] so ln is finite
        let dt = mean_interarrival.mul_f64(-(1.0 - arrivals.next_f64()).ln());
        std::thread::sleep(dt);
    }
    for h in pending {
        h.wait()?;
    }
    let shards = router.shards();
    let mut it = shards.iter();
    let first = it.next().expect("router has at least one shard");
    let mut ttft = first.metrics.ttft_seconds.snapshot();
    let mut itl = first.metrics.itl_seconds.snapshot();
    for s in it {
        ttft.merge(&s.metrics.ttft_seconds.snapshot());
        itl.merge(&s.metrics.itl_seconds.snapshot());
    }
    Ok((ttft, itl))
}

/// Sum of every exposition sample named exactly `name` in a METRICS
/// render (counters merge unlabeled; shard-labeled gauges sum).
fn metric_sum(text: &str, name: &str) -> f64 {
    text.lines()
        .filter(|l| !l.starts_with('#'))
        .filter_map(|l| {
            let rest = l.strip_prefix(name)?;
            if !(rest.starts_with(' ') || rest.starts_with('{')) {
                return None;
            }
            l.rsplit(' ').next()?.parse::<f64>().ok()
        })
        .sum()
}

/// Fault-recovery leg: a supervised 2-shard native fleet serving `n`
/// streaming requests, optionally with a scripted coordinator kill.
/// Streams are collected on their own threads so the worst inter-token
/// gap is real wall-clock stall — for the chaos run that gap IS the
/// recovery latency (die → re-place → re-prefill → replay → next
/// token).  Returns (agg decode tok/s, worst gap ms, router).
fn run_fault_fleet(
    model: std::sync::Arc<swan::model::SwanModel>,
    cfg: &ServeConfig,
    plans: Vec<Option<std::sync::Arc<swan::shard::FaultPlan>>>,
    n_requests: usize,
    max_new: usize,
) -> anyhow::Result<(f64, f64, Router)> {
    let router = Router::launch_pipeline_from_model(model, cfg, plans)?;
    let mut rng = Pcg64::new(42);
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let prompt = format!(
            "{} the {} ",
            corpus::mixed_text(&mut rng.fork(i as u64), 180),
            corpus::NOUNS[i % corpus::NOUNS.len()]
        );
        let params = GenParams::new(max_new).stream(true);
        pending.push(router.submit(Request::with_params(0, &prompt, params))?);
    }
    let collectors: Vec<_> = pending
        .into_iter()
        .map(|h| {
            std::thread::spawn(move || -> anyhow::Result<(usize, f64)> {
                let mut last = std::time::Instant::now();
                let mut worst_gap = 0f64;
                loop {
                    match h.recv()? {
                        swan::api::Event::Token { .. } => {
                            worst_gap = worst_gap.max(last.elapsed().as_secs_f64());
                            last = std::time::Instant::now();
                        }
                        swan::api::Event::Done(r) => return Ok((r.stats.decode_steps, worst_gap)),
                        swan::api::Event::Error { message, .. } => {
                            anyhow::bail!("request lost: {message}")
                        }
                    }
                }
            })
        })
        .collect();
    let (mut decoded, mut worst_gap) = (0usize, 0f64);
    for c in collectors {
        let (steps, gap) = c.join().expect("collector thread panicked")?;
        decoded += steps;
        worst_gap = worst_gap.max(gap);
    }
    let tps = decoded as f64 / t0.elapsed().as_secs_f64();
    Ok((tps, worst_gap * 1e3, router))
}

fn main() {
    let dir = swan::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("e2e_serve: skipping (run `make artifacts` first)");
        return;
    }
    let n = 8usize;
    let max_new = 32usize;
    let workers = swan::swan::batch::WorkerPool::recommended_threads();
    println!("# e2e_serve ({n} requests, {max_new} new tokens each, ~180-char prompts)");
    for (label, cfg) in [
        ("dense baseline", ServeConfig { dense_baseline: true, ..Default::default() }),
        (
            "swan k=48 16-bit",
            ServeConfig { k_active: 48, mode: StorageMode::F16, ..Default::default() },
        ),
        (
            "swan k=32 16-bit",
            ServeConfig { k_active: 32, mode: StorageMode::F16, ..Default::default() },
        ),
        (
            "swan k=32 16-bit ∥",
            ServeConfig {
                k_active: 32,
                mode: StorageMode::F16,
                decode_workers: workers,
                ..Default::default()
            },
        ),
        (
            "swan k=32 8-bit",
            ServeConfig { k_active: 32, mode: StorageMode::F8, ..Default::default() },
        ),
        (
            "swan k=32 8-bit ∥",
            ServeConfig {
                k_active: 32,
                mode: StorageMode::F8,
                decode_workers: workers,
                ..Default::default()
            },
        ),
        (
            "swan k=16 8-bit",
            ServeConfig { k_active: 16, mode: StorageMode::F8, ..Default::default() },
        ),
    ] {
        match run_batch(cfg, n, max_new) {
            Ok(row) => println!("{label:<18} {row}"),
            Err(e) => println!("{label:<18} FAILED: {e:#}"),
        }
    }

    // shard scaling: aggregate decode throughput through the router at
    // shards {1,2,4} × concurrent-request batch {4,16} (least-queued
    // placement, swan k=32 16-bit, decode workers split across shards)
    println!("# shard_scaling ({max_new} new tokens each, ~180-char prompts)");
    for shards in [1usize, 2, 4] {
        for batch in [4usize, 16] {
            let cfg = ServeConfig {
                shards,
                balance: "least-queued".into(),
                k_active: 32,
                mode: StorageMode::F16,
                max_batch: batch,
                decode_workers: (workers / shards).max(1),
                ..Default::default()
            };
            let label = format!("shards={shards} batch={batch}");
            match run_shard_batch(cfg, batch, max_new) {
                Ok((_, row)) => println!("{label:<18} {row}"),
                Err(e) => println!("{label:<18} FAILED: {e:#}"),
            }
        }
    }

    // pipeline scaling: one pipeline group at stage depth {1,2,4} over
    // the rust-native model (layer-sharded serving), 8 concurrent
    // requests; machine-readable rows land in BENCH_pipeline.json so the
    // layer-sharding trajectory is tracked across PRs.  Every row —
    // including the depth-1 baseline — is built directly from
    // `pipeline::launch_group`, so the sweep varies ONLY stage depth,
    // never the execution backend (Router::launch would serve
    // pipeline=1 through the PJRT engine instead).
    println!("# pipeline_scaling ({max_new} new tokens each, ~180-char prompts)");
    let mut report = swan::util::stats::BenchReport::open("BENCH_pipeline.json");
    for stages in [1usize, 2, 4] {
        let cfg = ServeConfig {
            pipeline: stages,
            k_active: 32,
            mode: StorageMode::F16,
            max_batch: 8,
            decode_workers: (workers / stages).max(1),
            ..Default::default()
        };
        let label = format!("stages={stages}");
        match run_pipeline_batch(cfg, n, max_new) {
            Ok((tps, row)) => {
                println!("{label:<18} {row}");
                report.set("pipeline_scaling", &format!("stages{stages}_decode_tps"), tps);
            }
            Err(e) => println!("{label:<18} FAILED: {e:#}"),
        }
    }
    report.set("pipeline_scaling", "requests", n as f64);
    report.set("pipeline_scaling", "max_new", max_new as f64);
    if let Err(e) = report.save() {
        eprintln!("could not write {}: {e}", report.path().display());
    }

    // pool scaling: paged block pool vs per-sequence caches on the same
    // native pipeline path at batch {4,16,64} (both legs run through
    // `pipeline::launch_group`, so the sweep varies ONLY the storage
    // backend), plus a budget-bound leg that forces block-granular
    // preemption; rows land in BENCH_pool.json
    println!("# pool_scaling ({max_new} new tokens each, ~180-char prompts)");
    let mut pool_report = swan::util::stats::BenchReport::open("BENCH_pool.json");
    for batch in [4usize, 16, 64] {
        let base = ServeConfig {
            k_active: 32,
            mode: StorageMode::F16,
            max_batch: batch,
            decode_workers: workers,
            ..Default::default()
        };
        let label = format!("perseq batch={batch}");
        match run_pipeline_batch(base.clone(), batch, max_new) {
            Ok((tps, row)) => {
                println!("{label:<18} {row}");
                pool_report.set("pool_scaling", &format!("perseq_batch{batch}_decode_tps"), tps);
            }
            Err(e) => println!("{label:<18} FAILED: {e:#}"),
        }
        let label = format!("pool   batch={batch}");
        match run_pool_batch(ServeConfig { pool: true, block_tokens: 16, ..base }, batch, max_new)
        {
            Ok((tps, frag, _, row)) => {
                println!("{label:<18} {row}");
                pool_report.set("pool_scaling", &format!("pool_batch{batch}_decode_tps"), tps);
                if let Some(f) = frag {
                    pool_report.set("pool_scaling", &format!("pool_batch{batch}_frag_pct"), f);
                }
            }
            Err(e) => println!("{label:<18} FAILED: {e:#}"),
        }
    }
    // budget-bound leg: a tight block budget preempts mid-decode; the
    // victims requeue and replay, so every request still completes
    let tight = ServeConfig {
        pool: true,
        block_tokens: 16,
        mem_budget: 8 << 20,
        k_active: 32,
        mode: StorageMode::F16,
        max_batch: 16,
        decode_workers: workers,
        ..Default::default()
    };
    match run_pool_batch(tight, 16, max_new) {
        Ok((tps, _, preempted, row)) => {
            println!("{:<18} {row}", "pool   tight-mem");
            pool_report.set("pool_scaling", "tight_decode_tps", tps);
            pool_report.set("pool_scaling", "tight_preempted", preempted as f64);
        }
        Err(e) => println!("{:<18} FAILED: {e:#}", "pool   tight-mem"),
    }
    pool_report.set("pool_scaling", "max_new", max_new as f64);
    if let Err(e) = pool_report.save() {
        eprintln!("could not write {}: {e}", pool_report.path().display());
    }

    // latency SLO: open-loop Poisson arrivals; TTFT / inter-token-gap
    // percentiles come straight from the fleet's obs histograms (the
    // series METRICS exports), land in BENCH_obs.json
    let slo_requests = 16usize;
    println!(
        "# latency_slo ({slo_requests} requests, {max_new} new tokens each, \
         Poisson arrivals, 5 ms mean)"
    );
    let slo_cfg = ServeConfig {
        k_active: 32,
        mode: StorageMode::F16,
        max_batch: 4,
        decode_workers: workers,
        ..Default::default()
    };
    match run_latency_slo(slo_cfg, slo_requests, max_new, std::time::Duration::from_millis(5)) {
        Ok((ttft, itl)) => {
            let mut obs_report = swan::util::stats::BenchReport::open("BENCH_obs.json");
            for (name, snap) in [("ttft", &ttft), ("itl", &itl)] {
                println!(
                    "{name:<18} p50={} p95={} p99={} (n={})",
                    swan::util::stats::Summary::fmt_time(snap.quantile_ns(0.50)),
                    swan::util::stats::Summary::fmt_time(snap.quantile_ns(0.95)),
                    swan::util::stats::Summary::fmt_time(snap.quantile_ns(0.99)),
                    snap.count(),
                );
                for (q, frac) in [("p50", 0.50), ("p95", 0.95), ("p99", 0.99)] {
                    obs_report.set(
                        "latency_slo",
                        &format!("{name}_{q}_ms"),
                        snap.quantile_ns(frac) / 1e6,
                    );
                }
            }
            obs_report.set("latency_slo", "requests", slo_requests as f64);
            obs_report.set("latency_slo", "max_new", max_new as f64);
            if let Err(e) = obs_report.save() {
                eprintln!("could not write {}: {e}", obs_report.path().display());
            }
        }
        Err(e) => println!("{:<18} FAILED: {e:#}", "latency_slo"),
    }

    // fault recovery: the same supervised 2-shard native fleet serving
    // streaming requests, undisturbed vs with a scripted mid-decode
    // coordinator kill.  The chaos run's worst inter-token gap is the
    // end-to-end recovery latency (die → re-place → re-prefill → replay
    // committed tokens → next live token); replay-token overhead comes
    // from the fleet's own counters.  Rows land in BENCH_obs.json next
    // to the SLO percentiles.
    println!("# fault_recovery ({n} streaming requests, {max_new} new tokens each)");
    let fault = (|| -> anyhow::Result<()> {
        use swan::model::{SwanModel, WeightFile};
        use swan::swan::projection::ProjectionVariant;
        let fleet_cfg = ServeConfig {
            shards: 2,
            k_active: 32,
            mode: StorageMode::F16,
            max_batch: 8,
            decode_workers: (workers / 2).max(1),
            ..Default::default()
        };
        let wf = WeightFile::load(&dir.join(format!("weights_{}.bin", fleet_cfg.model)))?;
        let model = std::sync::Arc::new(SwanModel::load(&wf, ProjectionVariant::Calibrated, 0)?);
        let (base_tps, base_gap, _baseline) =
            run_fault_fleet(model.clone(), &fleet_cfg, vec![], n, max_new)?;
        println!(
            "{:<18} agg decode {base_tps:>7.1} tok/s | worst gap {base_gap:>8.2} ms",
            "undisturbed"
        );
        let plans = vec![Some(swan::shard::FaultPlan::kill_at(20)), None];
        let (chaos_tps, chaos_gap, router) =
            run_fault_fleet(model, &fleet_cfg, plans, n, max_new)?;
        let m = router.metrics_text();
        let deaths = metric_sum(&m, "swan_shard_deaths");
        let recovered = metric_sum(&m, "swan_requests_recovered");
        let replayed = metric_sum(&m, "swan_replay_tokens");
        println!(
            "{:<18} agg decode {chaos_tps:>7.1} tok/s | worst gap {chaos_gap:>8.2} ms | \
             deaths {deaths:.0} | recovered {recovered:.0} | replayed {replayed:.0} tokens",
            "kill mid-decode"
        );
        let mut fault_report = swan::util::stats::BenchReport::open("BENCH_obs.json");
        fault_report.set("fault_recovery", "baseline_decode_tps", base_tps);
        fault_report.set("fault_recovery", "baseline_worst_gap_ms", base_gap);
        fault_report.set("fault_recovery", "chaos_decode_tps", chaos_tps);
        fault_report.set("fault_recovery", "chaos_worst_gap_ms", chaos_gap);
        fault_report.set("fault_recovery", "shard_deaths", deaths);
        fault_report.set("fault_recovery", "requests_recovered", recovered);
        fault_report.set("fault_recovery", "replay_tokens", replayed);
        fault_report.set("fault_recovery", "requests", n as f64);
        fault_report.set("fault_recovery", "max_new", max_new as f64);
        fault_report.save()?;
        Ok(())
    })();
    if let Err(e) = fault {
        println!("{:<18} FAILED: {e:#}", "fault_recovery");
    }

    // api mix: the same fleet serving different request shapes — greedy,
    // top-p, repetition-penalty, streaming, and a per-request-k mix —
    // priced as aggregate decode tok/s and tracked in BENCH_api.json
    println!("# api_mix ({n} requests, {max_new} new tokens each)");
    let mut api_report = swan::util::stats::BenchReport::open("BENCH_api.json");
    let cfg = ServeConfig {
        k_active: 32,
        mode: StorageMode::F16,
        max_batch: 8,
        decode_workers: workers,
        ..Default::default()
    };
    match Router::launch(&dir, cfg) {
        Err(e) => println!("api_mix FAILED to launch: {e:#}"),
        Ok(router) => {
            let scenarios: Vec<(&str, Box<dyn Fn(u64) -> GenParams>)> = vec![
                ("greedy", Box::new(move |_| GenParams::new(max_new))),
                (
                    "top_p",
                    Box::new(move |i| {
                        GenParams::new(max_new).temperature(0.8).top_p(0.9).seed(i)
                    }),
                ),
                (
                    "rep_penalty",
                    Box::new(move |i| {
                        GenParams::new(max_new).temperature(0.8).repetition_penalty(1.2).seed(i)
                    }),
                ),
                ("stream", Box::new(move |_| GenParams::new(max_new).stream(true))),
                (
                    "mixed_k",
                    Box::new(move |i| {
                        GenParams::new(max_new).k_active(if i % 2 == 0 { 16 } else { 48 })
                    }),
                ),
            ];
            for (label, mk) in scenarios {
                match drive_params(&router, n, mk) {
                    Ok(tps) => {
                        println!("{label:<18} agg decode {tps:>7.1} tok/s");
                        api_report.set("api_mix", &format!("{label}_decode_tps"), tps);
                    }
                    Err(e) => println!("{label:<18} FAILED: {e:#}"),
                }
            }
            api_report.set("api_mix", "requests", n as f64);
            api_report.set("api_mix", "max_new", max_new as f64);
            if let Err(e) = api_report.save() {
                eprintln!("could not write {}: {e}", api_report.path().display());
            }
        }
    }

    // prefix reuse: one native pipeline group with --prefix-cache on; a
    // cold pass admits n prompts sharing a long preamble, then a warm
    // pass re-submits the identical prompts — every warm admission
    // attaches the cached full-block prefix copy-on-write and prefills
    // only the suffix.  TTFT (queue + prefill) comes from each
    // response's own stats; hit rate and prompt tokens saved from the
    // fleet counters.  Rows land in BENCH_prefix.json.  Seeds are
    // pinned so the warm pass reproduces the cold streams bit-exactly
    // (both passes run under prefix mode).
    println!(
        "# prefix_reuse ({n} requests x 2 passes, shared ~180-char preamble, {max_new} new tokens each)"
    );
    let prefix_leg = (|| -> anyhow::Result<()> {
        use swan::model::{SwanModel, WeightFile};
        use swan::shard::pipeline::launch_group;
        use swan::swan::projection::ProjectionVariant;
        let cfg = ServeConfig {
            prefix: true,
            block_tokens: 16,
            k_active: 32,
            mode: StorageMode::F16,
            max_batch: 8,
            decode_workers: workers,
            ..Default::default()
        };
        let wf = WeightFile::load(&dir.join(format!("weights_{}.bin", cfg.model)))?;
        let model = std::sync::Arc::new(SwanModel::load(&wf, ProjectionVariant::Calibrated, 0)?);
        let handle = launch_group(0, model, &cfg)?;
        let router =
            Router::from_handles(vec![handle], swan::shard::policy_from_name("round-robin")?);
        let mut rng = Pcg64::new(42);
        let preamble = corpus::mixed_text(&mut rng, 180);
        let prompts: Vec<String> = (0..n)
            .map(|i| format!("{preamble} the {} ", corpus::NOUNS[i % corpus::NOUNS.len()]))
            .collect();
        let run_pass = |label: &str| -> anyhow::Result<(f64, f64)> {
            let pending: Vec<_> = prompts
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    router.submit(Request::with_params(
                        0,
                        p,
                        GenParams::new(max_new).seed(i as u64),
                    ))
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            let mut ttft_ms: Vec<f64> = Vec::with_capacity(pending.len());
            for h in pending {
                let r = h.wait()?;
                ttft_ms.push((r.stats.queue_time + r.stats.prefill_time).as_secs_f64() * 1e3);
            }
            ttft_ms.sort_by(|a, b| a.total_cmp(b));
            let q = |f: f64| ttft_ms[((ttft_ms.len() - 1) as f64 * f).round() as usize];
            let (p50, p95) = (q(0.50), q(0.95));
            println!("{label:<18} ttft p50 {p50:>7.2} ms | p95 {p95:>7.2} ms");
            Ok((p50, p95))
        };
        let (cold_p50, cold_p95) = run_pass("cold pass")?;
        let (warm_p50, warm_p95) = run_pass("warm pass")?;
        let (mut hits, mut misses, mut saved) = (0u64, 0u64, 0u64);
        for s in router.shards() {
            hits += s.metrics.prefix_hits.get();
            misses += s.metrics.prefix_misses.get();
            saved += s.metrics.prefix_tokens_saved.get();
        }
        let admissions = hits + misses;
        let hit_rate =
            if admissions > 0 { 100.0 * hits as f64 / admissions as f64 } else { 0.0 };
        println!(
            "{:<18} hits {hits}/{admissions} admissions ({hit_rate:.1}%) | \
             {saved} prompt tokens saved",
            "reuse"
        );
        let mut report = swan::util::stats::BenchReport::open("BENCH_prefix.json");
        report.set("prefix_reuse", "cold_ttft_p50_ms", cold_p50);
        report.set("prefix_reuse", "cold_ttft_p95_ms", cold_p95);
        report.set("prefix_reuse", "warm_ttft_p50_ms", warm_p50);
        report.set("prefix_reuse", "warm_ttft_p95_ms", warm_p95);
        report.set("prefix_reuse", "hit_rate_pct", hit_rate);
        report.set("prefix_reuse", "tokens_saved", saved as f64);
        report.set("prefix_reuse", "requests_per_pass", n as f64);
        report.set("prefix_reuse", "max_new", max_new as f64);
        report.save()?;
        Ok(())
    })();
    if let Err(e) = prefix_leg {
        println!("{:<18} FAILED: {e:#}", "prefix_reuse");
    }
}
