//! Bench: the eviction-path winnowing (Algorithm 1 lines 7-11) —
//! top-k selection + quantization, sort vs partial-select implementations.

use swan::sparse::topk::{topk_indices, topk_indices_select};
use swan::sparse::{SparseVec, StorageMode};
use swan::util::stats::{bench_batched, Summary};
use swan::util::Pcg64;

fn main() {
    println!("# prune_topk");
    let mut rng = Pcg64::new(5);
    for &d in &[64usize, 128] {
        let rows: Vec<Vec<f32>> = (0..256).map(|_| rng.normal_vec(d)).collect();
        for &k in &[d / 4, d / 2, 3 * d / 4] {
            let sort_t = bench_batched(3, 15, 1, || {
                for r in &rows {
                    std::hint::black_box(topk_indices(r, k));
                }
            });
            let sel_t = bench_batched(3, 15, 1, || {
                for r in &rows {
                    std::hint::black_box(topk_indices_select(r, k));
                }
            });
            let full_t = bench_batched(3, 15, 1, || {
                for r in &rows {
                    std::hint::black_box(SparseVec::prune(r, k, StorageMode::F16));
                }
            });
            println!(
                "d={d:<4} k={k:<4} sort {:>12} | select {:>12} ({:.2}x) | prune+f16 {:>12}",
                Summary::fmt_time(sort_t.median_ns / 256.0),
                Summary::fmt_time(sel_t.median_ns / 256.0),
                sort_t.median_ns / sel_t.median_ns,
                Summary::fmt_time(full_t.median_ns / 256.0),
            );
        }
    }
}
