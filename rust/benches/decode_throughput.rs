//! Bench: decode throughput.
//!
//! Part 1 (always runs): the rust-native batched decode path — serial
//! `decode_step` per sequence vs `decode_step_batch` fanned across the
//! worker pool, at batch sizes {1, 4, 16, 64}, repeated **per kernel
//! path** (scalar, and AVX2 where the host supports it).  Same
//! arithmetic, different scheduling/kernels, so tokens/sec is the whole
//! story.  Per-path tokens/sec land in `BENCH_kernels.json`
//! (`decode_throughput` section).
//!
//! Part 2 (always runs): serial vs pool-fanned `prefill` on one long
//! prompt (`prefill` section of the report).
//!
//! Part 3 (needs `make artifacts`): PJRT decode-step latency per shape
//! bucket, SWAN vs dense baseline graphs.

use swan::config::ModelConfig;
use swan::kvcache::PolicyKind;
use swan::model::transformer::{SequenceState, SwanModel};
use swan::runtime::engine::{HostTensor, LoadedModel};
use swan::simd::Kernels;
use swan::sparse::StorageMode;
use swan::swan::batch::WorkerPool;
use swan::tensor::ops::argmax;
use swan::util::stats::{bench, BenchReport, Summary};
use swan::util::Pcg64;

fn bench_cfg() -> ModelConfig {
    ModelConfig {
        name: "swan-bench".into(),
        d_model: 128,
        n_layers: 4,
        n_q_heads: 8,
        n_kv_heads: 4,
        d_head: 16,
        d_ff: 256,
        vocab: 96,
        rope_theta: 10000.0,
        norm_eps: 1e-5,
    }
}

fn fresh_states(model: &SwanModel, pf: &swan::model::transformer::Prefill, n: usize) -> Vec<SequenceState> {
    (0..n)
        .map(|_| {
            let mut st = SequenceState::new(
                model,
                PolicyKind::Swan { k_active: 8, buffer: 16, mode: StorageMode::F16 },
            );
            st.load_prefill(pf);
            st
        })
        .collect()
}

fn native_batched_section(ks: Kernels, report: &mut BenchReport) {
    swan::simd::set_active(ks);
    let model = SwanModel::synthetic(bench_cfg(), 11);
    let prompt: Vec<u32> = (0..48).map(|i| (i * 7 % 96) as u32).collect();
    let pf = model.prefill(&prompt);
    let steps = 32usize;
    let workers = WorkerPool::recommended_threads();

    println!(
        "# decode_throughput: native batched decode, kernels={} ({} layers, d={}, {} q / {} kv \
         heads; {} steps/seq, {} workers)",
        ks.label(), model.cfg.n_layers, model.cfg.d_model, model.cfg.n_q_heads,
        model.cfg.n_kv_heads, steps, workers
    );
    println!(
        "{:<8} {:>14} {:>16} {:>9}",
        "batch", "serial tok/s", "parallel tok/s", "speedup"
    );

    for &batch in &[1usize, 4, 16, 64] {
        // serial: one decode_step per sequence per iteration
        let mut states = fresh_states(&model, &pf, batch);
        let mut toks = vec![argmax(&pf.logits) as u32; batch];
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            for (st, tok) in states.iter_mut().zip(toks.iter_mut()) {
                let logits = model.decode_step(st, *tok);
                *tok = argmax(&logits) as u32;
            }
        }
        let serial_s = t0.elapsed().as_secs_f64();
        let serial_tps = (batch * steps) as f64 / serial_s;
        let serial_tokens = toks.clone();

        // parallel: lock-step decode_step_batch over the pool
        let mut pool = WorkerPool::new(workers);
        let mut states = fresh_states(&model, &pf, batch);
        let mut toks = vec![argmax(&pf.logits) as u32; batch];
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            let logits = model.decode_step_batch(&mut states, &toks, &mut pool);
            for (tok, l) in toks.iter_mut().zip(&logits) {
                *tok = argmax(l) as u32;
            }
        }
        let par_s = t0.elapsed().as_secs_f64();
        let par_tps = (batch * steps) as f64 / par_s;

        assert_eq!(serial_tokens, toks, "parallel decode diverged from serial");
        println!(
            "{batch:<8} {serial_tps:>14.1} {par_tps:>16.1} {:>8.2}x",
            par_tps / serial_tps
        );
        report.set(
            "decode_throughput",
            &format!("{}_batch{batch}_serial_tps", ks.label()),
            serial_tps,
        );
        report.set(
            "decode_throughput",
            &format!("{}_batch{batch}_parallel_tps", ks.label()),
            par_tps,
        );
    }
    println!();
}

/// Serial vs pool-fanned prefill on one long prompt (ROADMAP "parallel
/// prefill" item): per-layer projection/attention/MLP phases fanned
/// across the worker pool, results bit-identical by contract.
fn prefill_section(report: &mut BenchReport) {
    let model = SwanModel::synthetic(bench_cfg(), 11);
    let prompt: Vec<u32> = (0..256).map(|i| (i * 7 % 96) as u32).collect();
    let workers = WorkerPool::recommended_threads();

    let t_serial = bench(1, 5, || {
        std::hint::black_box(model.prefill(&prompt));
    });
    let mut pool = WorkerPool::new(workers);
    let t_par = bench(1, 5, || {
        std::hint::black_box(model.prefill_with_pool(&prompt, &mut pool));
    });
    let speedup = t_serial.median_ns / t_par.median_ns;
    println!(
        "# decode_throughput: prefill ({} tokens): serial {} vs {} workers {}  ({speedup:.2}x)\n",
        prompt.len(),
        Summary::fmt_time(t_serial.median_ns),
        workers,
        Summary::fmt_time(t_par.median_ns)
    );
    report.set("prefill", "serial_ns", t_serial.median_ns);
    report.set("prefill", "parallel_ns", t_par.median_ns);
    report.set("prefill", "workers", workers as f64);
}

fn pjrt_section() {
    let dir = swan::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("decode_throughput (PJRT): skipping (run `make artifacts` first)");
        return;
    }
    let lm = LoadedModel::open(&dir, "swan-nano-gqa").expect("artifacts");
    let arts = lm.store.model("swan-nano-gqa").unwrap();
    let cfg = arts.config.clone();
    let (nl, nkv, dh, buf) = (cfg.n_layers, cfg.n_kv_heads, cfg.d_head, arts.buf);
    let mut rng = Pcg64::new(9);

    println!("# decode_throughput (PJRT CPU, {} graphs)", arts.graphs.len());
    for (l_cap, k) in arts.decode_buckets() {
        let graph = format!("decode_l{l_cap}_k{k}");
        let sp_shape = vec![nl, nkv, l_cap, k];
        let spn = nl * nkv * l_cap * k;
        let args = vec![
            HostTensor::scalar_i32(5),
            HostTensor::scalar_i32((l_cap / 2) as i32),
            HostTensor::f32(rng.normal_vec(spn), sp_shape.clone()),
            HostTensor::i32((0..spn).map(|i| (i % dh) as i32).collect(), sp_shape.clone()),
            HostTensor::f32(rng.normal_vec(spn), sp_shape.clone()),
            HostTensor::i32((0..spn).map(|i| (i % dh) as i32).collect(), sp_shape),
            HostTensor::f32(rng.normal_vec(nl * nkv * buf * dh), vec![nl, nkv, buf, dh]),
            HostTensor::f32(rng.normal_vec(nl * nkv * buf * dh), vec![nl, nkv, buf, dh]),
            HostTensor::f32(vec![1.0; l_cap], vec![l_cap]),
            HostTensor::f32(vec![1.0; buf], vec![buf]),
        ];
        // compile outside the timed region
        lm.execute(&graph, &args).expect("warmup");
        let t = bench(2, 20, || {
            std::hint::black_box(lm.execute(&graph, &args).unwrap());
        });
        println!(
            "{:<22} {:>12}/step  ({:>8.1} tok/s)",
            graph,
            Summary::fmt_time(t.median_ns),
            1e9 / t.median_ns
        );
    }

    // dense baseline graph
    let l_cap = 512usize;
    let graph = "decode_dense_l512";
    let args = vec![
        HostTensor::scalar_i32(5),
        HostTensor::scalar_i32(256),
        HostTensor::f32(rng.normal_vec(nl * nkv * l_cap * dh), vec![nl, nkv, l_cap, dh]),
        HostTensor::f32(rng.normal_vec(nl * nkv * l_cap * dh), vec![nl, nkv, l_cap, dh]),
        HostTensor::f32(vec![1.0; l_cap], vec![l_cap]),
    ];
    lm.execute(graph, &args).expect("warmup");
    let t = bench(2, 20, || {
        std::hint::black_box(lm.execute(graph, &args).unwrap());
    });
    println!(
        "{:<22} {:>12}/step  ({:>8.1} tok/s)",
        graph,
        Summary::fmt_time(t.median_ns),
        1e9 / t.median_ns
    );
}

fn main() {
    let mut report = BenchReport::open(
        &std::env::var("SWAN_BENCH_JSON").unwrap_or_else(|_| "BENCH_kernels.json".into()),
    );
    for ks in Kernels::available() {
        native_batched_section(ks, &mut report);
    }
    swan::simd::set_active(Kernels::detect());
    prefill_section(&mut report);
    match report.save() {
        Ok(()) => println!("(wrote {})\n", report.path().display()),
        Err(e) => eprintln!("warning: could not write bench report: {e}"),
    }
    pjrt_section();
}
