//! Bench: PJRT decode-step latency per shape bucket, SWAN vs dense
//! baseline graphs — the serving-path compute comparison (needs
//! `make artifacts`).

use swan::runtime::engine::{HostTensor, LoadedModel};
use swan::util::stats::{bench, Summary};
use swan::util::Pcg64;

fn main() {
    let dir = swan::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("decode_throughput: skipping (run `make artifacts` first)");
        return;
    }
    let lm = LoadedModel::open(&dir, "swan-nano-gqa").expect("artifacts");
    let arts = lm.store.model("swan-nano-gqa").unwrap();
    let cfg = arts.config.clone();
    let (nl, nkv, dh, buf) = (cfg.n_layers, cfg.n_kv_heads, cfg.d_head, arts.buf);
    let mut rng = Pcg64::new(9);

    println!("# decode_throughput (PJRT CPU, {} graphs)", arts.graphs.len());
    for (l_cap, k) in arts.decode_buckets() {
        let graph = format!("decode_l{l_cap}_k{k}");
        let sp_shape = vec![nl, nkv, l_cap, k];
        let spn = nl * nkv * l_cap * k;
        let args = vec![
            HostTensor::scalar_i32(5),
            HostTensor::scalar_i32((l_cap / 2) as i32),
            HostTensor::f32(rng.normal_vec(spn), sp_shape.clone()),
            HostTensor::i32((0..spn).map(|i| (i % dh) as i32).collect(), sp_shape.clone()),
            HostTensor::f32(rng.normal_vec(spn), sp_shape.clone()),
            HostTensor::i32((0..spn).map(|i| (i % dh) as i32).collect(), sp_shape),
            HostTensor::f32(rng.normal_vec(nl * nkv * buf * dh), vec![nl, nkv, buf, dh]),
            HostTensor::f32(rng.normal_vec(nl * nkv * buf * dh), vec![nl, nkv, buf, dh]),
            HostTensor::f32(vec![1.0; l_cap], vec![l_cap]),
            HostTensor::f32(vec![1.0; buf], vec![buf]),
        ];
        // compile outside the timed region
        lm.execute(&graph, &args).expect("warmup");
        let t = bench(2, 20, || {
            std::hint::black_box(lm.execute(&graph, &args).unwrap());
        });
        println!(
            "{:<22} {:>12}/step  ({:>8.1} tok/s)",
            graph,
            Summary::fmt_time(t.median_ns),
            1e9 / t.median_ns
        );
    }

    // dense baseline graph
    let l_cap = 512usize;
    let graph = "decode_dense_l512";
    let args = vec![
        HostTensor::scalar_i32(5),
        HostTensor::scalar_i32(256),
        HostTensor::f32(rng.normal_vec(nl * nkv * l_cap * dh), vec![nl, nkv, l_cap, dh]),
        HostTensor::f32(rng.normal_vec(nl * nkv * l_cap * dh), vec![nl, nkv, l_cap, dh]),
        HostTensor::f32(vec![1.0; l_cap], vec![l_cap]),
    ];
    lm.execute(graph, &args).expect("warmup");
    let t = bench(2, 20, || {
        std::hint::black_box(lm.execute(graph, &args).unwrap());
    });
    println!(
        "{:<22} {:>12}/step  ({:>8.1} tok/s)",
        graph,
        Summary::fmt_time(t.median_ns),
        1e9 / t.median_ns
    );
}
