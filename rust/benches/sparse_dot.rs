//! Bench: the decompression-free primitives — sparse-dense score product
//! and scatter-add output — vs their dense counterparts, across k_active,
//! plus the CSR store walk per kernel path (scalar vs AVX2, unpadded vs
//! lane-padded rows).  Per-path numbers land in `BENCH_kernels.json`
//! (`sparse_dot` section, ns per row) so the trajectory is tracked across
//! PRs.

use swan::simd::Kernels;
use swan::sparse::{SparseStore, SparseVec, StorageMode};
use swan::tensor::ops::dot;
use swan::util::stats::{bench_batched, BenchReport, Summary};
use swan::util::Pcg64;

/// The CSR walk on every kernel path × row layout: the tentpole
/// comparison — same rows, same query, different kernels — recorded
/// machine-readably.
fn kernel_path_section(d: usize, n: usize, report: &mut BenchReport) {
    let mut rng = Pcg64::new(7);
    let q = rng.normal_vec(d);
    let rows: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d)).collect();
    let kernels = Kernels::available();

    println!("# CSR store walk by kernel path (d_h={d}, {n} rows/iter)");
    println!(
        "{:<34} {:>12} {:>12}",
        "kernel / layout / k", "scores", "scatter-add"
    );
    for &k in &[16usize, 32, 64, 128] {
        for lane in [1usize, 8] {
            let mut store = SparseStore::with_capacity_lanes(n, k, lane);
            for r in &rows {
                store.push_pruned(r, k, StorageMode::F32);
            }
            let w = vec![1.0 / n as f32; n];
            for ks in &kernels {
                let mut scores: Vec<f32> = Vec::with_capacity(store.len());
                let mut msum = 0.0f32;
                let t_scores = bench_batched(3, 15, 2, || {
                    scores.clear();
                    msum += store.scores_max_into_with(*ks, &q, 0.5, &mut scores);
                    std::hint::black_box(&scores);
                });
                let mut acc = vec![0.0f32; d];
                let t_axpy = bench_batched(3, 15, 2, || {
                    acc.iter_mut().for_each(|a| *a = 0.0);
                    store.axpy_all_with(*ks, &w, &mut acc);
                    std::hint::black_box(&acc);
                });
                std::hint::black_box(msum);
                let scores_row = t_scores.median_ns / n as f64;
                let axpy_row = t_axpy.median_ns / n as f64;
                println!(
                    "{:<34} {:>12} {:>12}",
                    format!("{} lane={lane} k={k}", ks.label()),
                    Summary::fmt_time(scores_row),
                    Summary::fmt_time(axpy_row)
                );
                let tag = format!("{}_lane{lane}_k{k}", ks.label());
                report.set("sparse_dot", &format!("{tag}_scores_ns_per_row"), scores_row);
                report.set("sparse_dot", &format!("{tag}_axpy_ns_per_row"), axpy_row);
            }
        }
    }
    println!();
}

fn main() {
    let mut report = BenchReport::open(
        &std::env::var("SWAN_BENCH_JSON").unwrap_or_else(|_| "BENCH_kernels.json".into()),
    );
    report.set_str("meta", "kernels_detected", swan::simd::Kernels::detect().label());
    kernel_path_section(128, 1024, &mut report);
    match report.save() {
        Ok(()) => println!("(wrote {})\n", report.path().display()),
        Err(e) => eprintln!("warning: could not write bench report: {e}"),
    }
    let d = 128usize;
    let n = 1024usize; // cache rows per iteration
    let mut rng = Pcg64::new(3);
    let q = rng.normal_vec(d);
    let rows: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d)).collect();

    println!("# sparse_dot (d_h={d}, {n} rows/iter)");
    let mut out_acc = 0.0f32;
    let dense_t = bench_batched(3, 15, 2, || {
        let mut s = 0.0f32;
        for r in &rows {
            s += dot(r, &q);
        }
        out_acc += s;
        std::hint::black_box(s);
    });
    println!(
        "{:<28} {:>14}  (per row {:>10})",
        "dense dot",
        Summary::fmt_time(dense_t.median_ns),
        Summary::fmt_time(dense_t.median_ns / n as f64)
    );

    for &k in &[16usize, 32, 64, 96, 128] {
        let sparse: Vec<SparseVec> =
            rows.iter().map(|r| SparseVec::prune(r, k, StorageMode::F32)).collect();
        let t = bench_batched(3, 15, 2, || {
            let mut s = 0.0f32;
            for sv in &sparse {
                s += sv.dot_dense(&q);
            }
            out_acc += s;
            std::hint::black_box(s);
        });
        println!(
            "{:<28} {:>14}  (per row {:>10}, vs dense {:.2}x)",
            format!("sparse dot k={k}"),
            Summary::fmt_time(t.median_ns),
            Summary::fmt_time(t.median_ns / n as f64),
            dense_t.median_ns / t.median_ns
        );
    }

    // scatter-add output side
    let w = 1.0 / n as f32;
    for &k in &[16usize, 32, 64] {
        let sparse: Vec<SparseVec> =
            rows.iter().map(|r| SparseVec::prune(r, k, StorageMode::F32)).collect();
        let mut acc = vec![0.0f32; d];
        let t = bench_batched(3, 15, 2, || {
            acc.iter_mut().for_each(|a| *a = 0.0);
            for sv in &sparse {
                sv.axpy_into(w, &mut acc);
            }
            std::hint::black_box(&acc);
        });
        println!(
            "{:<28} {:>14}  (per row {:>10})",
            format!("scatter-add k={k}"),
            Summary::fmt_time(t.median_ns),
            Summary::fmt_time(t.median_ns / n as f64)
        );
    }
    std::hint::black_box(out_acc);
}
