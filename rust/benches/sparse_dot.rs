//! Bench: the decompression-free primitives — sparse-dense score product
//! and scatter-add output — vs their dense counterparts, across k_active.
//! This is the per-token saving that Eq. 2's denominator (d_h - k) models.

use swan::sparse::{SparseVec, StorageMode};
use swan::tensor::ops::dot;
use swan::util::stats::{bench_batched, Summary};
use swan::util::Pcg64;

fn main() {
    let d = 128usize;
    let n = 1024usize; // cache rows per iteration
    let mut rng = Pcg64::new(3);
    let q = rng.normal_vec(d);
    let rows: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(d)).collect();

    println!("# sparse_dot (d_h={d}, {n} rows/iter)");
    let mut out_acc = 0.0f32;
    let dense_t = bench_batched(3, 15, 2, || {
        let mut s = 0.0f32;
        for r in &rows {
            s += dot(r, &q);
        }
        out_acc += s;
        std::hint::black_box(s);
    });
    println!(
        "{:<28} {:>14}  (per row {:>10})",
        "dense dot",
        Summary::fmt_time(dense_t.median_ns),
        Summary::fmt_time(dense_t.median_ns / n as f64)
    );

    for &k in &[16usize, 32, 64, 96, 128] {
        let sparse: Vec<SparseVec> =
            rows.iter().map(|r| SparseVec::prune(r, k, StorageMode::F32)).collect();
        let t = bench_batched(3, 15, 2, || {
            let mut s = 0.0f32;
            for sv in &sparse {
                s += sv.dot_dense(&q);
            }
            out_acc += s;
            std::hint::black_box(s);
        });
        println!(
            "{:<28} {:>14}  (per row {:>10}, vs dense {:.2}x)",
            format!("sparse dot k={k}"),
            Summary::fmt_time(t.median_ns),
            Summary::fmt_time(t.median_ns / n as f64),
            dense_t.median_ns / t.median_ns
        );
    }

    // scatter-add output side
    let w = 1.0 / n as f32;
    for &k in &[16usize, 32, 64] {
        let sparse: Vec<SparseVec> =
            rows.iter().map(|r| SparseVec::prune(r, k, StorageMode::F32)).collect();
        let mut acc = vec![0.0f32; d];
        let t = bench_batched(3, 15, 2, || {
            acc.iter_mut().for_each(|a| *a = 0.0);
            for sv in &sparse {
                sv.axpy_into(w, &mut acc);
            }
            std::hint::black_box(&acc);
        });
        println!(
            "{:<28} {:>14}  (per row {:>10})",
            format!("scatter-add k={k}"),
            Summary::fmt_time(t.median_ns),
            Summary::fmt_time(t.median_ns / n as f64)
        );
    }
    std::hint::black_box(out_acc);
}
