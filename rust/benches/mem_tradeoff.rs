//! Bench/table: Fig 2a memory trade-off measured on *actual stored
//! bytes* of a HybridCache (not just the closed form), plus the §1
//! motivation calculator.

use swan::sparse::memory::{compression_ratio, human_bytes, MemoryModel, StorageMode};
use swan::swan::hybrid_cache::{HybridCache, SwanParams};
use swan::util::Pcg64;

fn main() {
    let d = 128usize;
    let n_tokens = 4096usize;
    println!("# mem_tradeoff (d_h={d}, {n_tokens} tokens, buffer=128)");
    println!(
        "{:<10} {:<8} {:>14} {:>12} {:>12}",
        "retention", "mode", "measured", "ratio", "formula"
    );
    let mut rng = Pcg64::new(2);
    let stream: Vec<(Vec<f32>, Vec<f32>)> =
        (0..n_tokens).map(|_| (rng.normal_vec(d), rng.normal_vec(d))).collect();
    for &mode in &[StorageMode::F16, StorageMode::F8] {
        for &ret in &[0.9f64, 0.75, 0.66, 0.5, 0.3, 0.125] {
            let k = (ret * d as f64).round() as usize;
            let mut cache = HybridCache::new(d, SwanParams::new(k, 128, mode));
            for (kv, vv) in &stream {
                cache.append(kv, vv);
            }
            let dense = cache.dense_equiv_bytes();
            let used = cache.storage_bytes();
            println!(
                "{:<10.3} {:<8} {:>14} {:>12.3} {:>12.3}",
                ret,
                mode.label(),
                human_bytes(used),
                used as f64 / dense as f64,
                compression_ratio(d, k, mode),
            );
        }
    }

    println!("\n# §1 motivation (Llama-2 7B)");
    let m = MemoryModel::llama2_7b();
    println!(
        "dense @32k/b16: {} (paper ~256 GB); swan k=64/16b: {}; k=64/8b: {}",
        human_bytes(m.dense_bytes(32 * 1024, 16)),
        human_bytes(m.swan_bytes(32 * 1024, 128, 64, StorageMode::F16) * 16),
        human_bytes(m.swan_bytes(32 * 1024, 128, 64, StorageMode::F8) * 16),
    );
}
