"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: the pytest suite asserts the Pallas
kernels (interpret=True) match these implementations to float tolerance on
hypothesis-generated shapes.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def rotate_ref(x: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """Rotate vectors by an orthogonal projection: x[..., d] @ p[d, d]."""
    return x @ p


def topk_prune_ref(x: jnp.ndarray, k: int):
    """Magnitude top-k prune of each row of x[N, d].

    Returns (values[N, k], indices[N, k]) — the k largest-|.| entries per
    row, with original signs, ordered by descending magnitude (ties broken
    by lower index first, via the stable argsort on negated magnitudes).
    """
    order = jnp.argsort(-jnp.abs(x), axis=-1, stable=True)
    idx = order[..., :k]
    vals = jnp.take_along_axis(x, idx, axis=-1)
    return vals, idx.astype(jnp.int32)


def sparse_scores_ref(q: jnp.ndarray, kvals: jnp.ndarray, kidx: jnp.ndarray) -> jnp.ndarray:
    """Decompression-free score: s[l] = sum_j kvals[l, j] * q[kidx[l, j]]."""
    return jnp.sum(kvals * q[kidx], axis=-1)


def sparse_output_ref(w: jnp.ndarray, vvals: jnp.ndarray, vidx: jnp.ndarray, d: int) -> jnp.ndarray:
    """Decompression-free output: out[t] = sum_l w[l] * scatter(vvals[l] at vidx[l])[t]."""
    contrib = w[:, None] * vvals  # [L, k]
    return jnp.zeros((d,), dtype=w.dtype).at[vidx.reshape(-1)].add(contrib.reshape(-1))


def swan_attention_ref(
    qhat: jnp.ndarray,      # [d]
    kvals: jnp.ndarray,     # [Ls, k]
    kidx: jnp.ndarray,      # [Ls, k] int32
    vvals: jnp.ndarray,     # [Ls, k]
    vidx: jnp.ndarray,      # [Ls, k] int32
    kbuf: jnp.ndarray,      # [B, d] dense (buffer + current token rows)
    vbuf: jnp.ndarray,      # [B, d]
    smask: jnp.ndarray,     # [Ls] 1.0 = live, 0.0 = padding
    bmask: jnp.ndarray,     # [B]
) -> jnp.ndarray:
    """Hybrid-cache attention (Algorithm 1, lines 13-17) for one head.

    Attention over the concatenation [sparse cache ; dense buffer] without
    reconstructing the sparse vectors.
    """
    d = qhat.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=qhat.dtype))
    s_sparse = sparse_scores_ref(qhat, kvals, kidx) * scale
    s_buf = (kbuf @ qhat) * scale
    s_sparse = jnp.where(smask > 0, s_sparse, NEG_INF)
    s_buf = jnp.where(bmask > 0, s_buf, NEG_INF)
    s = jnp.concatenate([s_sparse, s_buf])
    m = jnp.max(s)
    e = jnp.exp(s - m)
    w = e / jnp.sum(e)
    w_sparse, w_buf = w[: kvals.shape[0]], w[kvals.shape[0]:]
    out = sparse_output_ref(w_sparse, vvals, vidx, d) + w_buf @ vbuf
    return out


def dense_attention_ref(q: jnp.ndarray, kcache: jnp.ndarray, vcache: jnp.ndarray,
                        mask: jnp.ndarray) -> jnp.ndarray:
    """Standard dense decode attention for one head (baseline oracle)."""
    d = q.shape[-1]
    s = (kcache @ q) / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    s = jnp.where(mask > 0, s, NEG_INF)
    m = jnp.max(s)
    e = jnp.exp(s - m)
    w = e / jnp.sum(e)
    return w @ vcache
