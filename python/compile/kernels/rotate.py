"""L1 Pallas kernel: runtime rotation of query/key vectors by P_QK.

RoPE is position-dependent, so P_QK cannot be absorbed into W_Q/W_K
(§4.2); this kernel applies the d_h x d_h orthogonal rotation at each
decode step.  Cost is the fixed 2*d_h^2-FLOP overhead in the Eq. 2
break-even analysis.  A (N, d_h) x (d_h, d_h) tile fits VMEM for every
configuration we ship; on TPU this is the only MXU-shaped op in the
decode path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rotate_kernel(x_ref, p_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], p_ref[...])


def rotate(x: jnp.ndarray, p: jnp.ndarray) -> jnp.ndarray:
    """Rotate x[N, d] by the orthogonal matrix p[d, d] -> x @ p."""
    return pl.pallas_call(
        _rotate_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, p)
