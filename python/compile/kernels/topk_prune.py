"""L1 Pallas kernel: magnitude top-k pruning (Algorithm 1, lines 7-11).

Winnows a block of rotated vectors to their k_active most significant
dimensions, emitting (values, indices) — the sparse representation stored
in the historical cache.  The sort runs entirely in VMEM on a
(block_N, d_h) tile; on TPU this is a VPU sort, on the interpret path it
lowers to an XLA variadic sort.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_prune_kernel(k: int, x_ref, vals_ref, idx_ref):
    x = x_ref[...]                                     # [N, d]
    order = jnp.argsort(-jnp.abs(x), axis=-1, stable=True)
    idx = order[..., :k]                               # [N, k]
    vals = jnp.take_along_axis(x, idx, axis=-1)
    vals_ref[...] = vals
    idx_ref[...] = idx.astype(jnp.int32)


def topk_prune(x: jnp.ndarray, k: int):
    """Prune rows of x[N, d] to top-k magnitude entries.

    Returns (values[N, k] f32, indices[N, k] i32), magnitude-descending.
    """
    n, _ = x.shape
    return pl.pallas_call(
        functools.partial(_topk_prune_kernel, k),
        out_shape=(
            jax.ShapeDtypeStruct((n, k), x.dtype),
            jax.ShapeDtypeStruct((n, k), jnp.int32),
        ),
        interpret=True,
    )(x)
