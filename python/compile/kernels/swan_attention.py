"""L1 Pallas kernel: SWAN hybrid-cache decode attention (Algorithm 1).

One grid step processes one (kv-head) worth of hybrid cache for a single
query vector.  The sparse half of the cache is the paper's winnowed store:
per-token (values, indices) arrays of the top-k_active rotated dimensions;
the dense half is the recency buffer (plus the current token's row).  The
kernel computes attention *directly* on this representation — scores via a
gather (sparse-dense mat-vec), the output via a scatter-add — with no
decompression/reconstruction of d_h-dim vectors.

Hardware adaptation (paper targets GPU/HBM): on TPU the BlockSpec streams
the (block_L, k) sparse tiles HBM->VMEM; gathers/scatter-adds map to VPU
lanes (decode is a mat-vec: MXU is structurally idle, the win is bytes
moved, Eq. 1).  Kernels are lowered with interpret=True here because the
CPU PJRT plugin cannot execute Mosaic custom-calls; the HLO produced is
plain gather/scatter/reduce ops that any backend runs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _swan_attention_kernel(q_ref, kvals_ref, kidx_ref, vvals_ref, vidx_ref,
                           kbuf_ref, vbuf_ref, smask_ref, bmask_ref, o_ref):
    qhat = q_ref[...]            # [d]
    kvals = kvals_ref[...]       # [Ls, k]
    kidx = kidx_ref[...]         # [Ls, k]
    vvals = vvals_ref[...]
    vidx = vidx_ref[...]
    kbuf = kbuf_ref[...]         # [B, d]
    vbuf = vbuf_ref[...]
    smask = smask_ref[...]       # [Ls]
    bmask = bmask_ref[...]       # [B]

    d = qhat.shape[-1]
    ls = kvals.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=qhat.dtype))

    # --- scores: sparse-dense mat-vec (gather, no reconstruction) ---
    gathered = jnp.take(qhat, kidx, axis=0)            # [Ls, k]
    s_sparse = jnp.sum(kvals * gathered, axis=-1) * scale
    s_buf = jnp.dot(kbuf, qhat) * scale                # [B]
    s_sparse = jnp.where(smask > 0, s_sparse, NEG_INF)
    s_buf = jnp.where(bmask > 0, s_buf, NEG_INF)

    # --- numerically-stable softmax over the hybrid score vector ---
    m = jnp.maximum(jnp.max(s_sparse), jnp.max(s_buf))
    e_sparse = jnp.exp(s_sparse - m)
    e_buf = jnp.exp(s_buf - m)
    z = jnp.sum(e_sparse) + jnp.sum(e_buf)
    w_sparse = e_sparse / z                            # [Ls]
    w_buf = e_buf / z                                  # [B]

    # --- output: scatter-add of weighted sparse values + dense buffer ---
    contrib = (w_sparse[:, None] * vvals).reshape(-1)  # [Ls*k]
    out = jnp.zeros((d,), dtype=qhat.dtype).at[vidx.reshape(-1)].add(contrib)
    out = out + jnp.dot(w_buf, vbuf)
    o_ref[...] = out


def swan_attention(qhat, kvals, kidx, vvals, vidx, kbuf, vbuf, smask, bmask):
    """Single-head hybrid attention. Shapes:

    qhat [d]; kvals/kidx/vvals/vidx [Ls, k]; kbuf/vbuf [B, d];
    smask [Ls]; bmask [B].  Returns out [d].
    """
    d = qhat.shape[-1]
    return pl.pallas_call(
        _swan_attention_kernel,
        out_shape=jax.ShapeDtypeStruct((d,), qhat.dtype),
        interpret=True,
    )(qhat, kvals, kidx, vvals, vidx, kbuf, vbuf, smask, bmask)


@functools.partial(jax.jit, static_argnames=())
def swan_attention_heads(qhat, kvals, kidx, vvals, vidx, kbuf, vbuf, smask, bmask):
    """vmap over kv-heads: qhat [H, d], caches [H, Ls, k], buffers [H, B, d]."""
    fn = jax.vmap(swan_attention, in_axes=(0, 0, 0, 0, 0, 0, 0, None, None))
    return fn(qhat, kvals, kidx, vvals, vidx, kbuf, vbuf, smask, bmask)
