"""L2: swan-nano transformer in JAX (MHA + GQA variants).

Three entry points matter downstream:

  * ``dense_forward``      — training-time causal forward (original weights).
  * ``swan_prefill``       — prompt phase in the *rotated* space: emits
                             logits plus the rotated k̂/v̂ history the rust
                             coordinator splits into buffer + sparse cache.
  * ``swan_decode_step``   — one autoregressive step over the hybrid cache,
                             calling the L1 Pallas kernels (rotate +
                             swan_attention).  This is the graph AOT-lowered
                             to HLO and executed from rust.

Weights are passed as a flat list in the deterministic order of
``common.swan_param_names`` / ``common.param_names`` so the HLO parameter
order is stable for the rust runtime.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from . import common
from .common import ModelConfig
from .kernels.rotate import rotate
from .kernels.swan_attention import swan_attention

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameter initialisation
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, np.ndarray]:
    """Initialise original (pre-SWAN) model parameters."""
    rng = np.random.default_rng(seed)
    d, dh, nq, nkv = cfg.d_model, cfg.d_head, cfg.n_q_heads, cfg.n_kv_heads

    def dense(shape, scale=None):
        scale = scale if scale is not None else (1.0 / np.sqrt(shape[0]))
        return (rng.normal(size=shape) * scale).astype(np.float32)

    params: Dict[str, np.ndarray] = {"embed": dense((cfg.vocab, d), 0.02)}
    for l in range(cfg.n_layers):
        params[f"l{l}.attn_norm"] = np.ones(d, np.float32)
        params[f"l{l}.wq"] = dense((d, nq * dh))
        params[f"l{l}.wk"] = dense((d, nkv * dh))
        params[f"l{l}.wv"] = dense((d, nkv * dh))
        params[f"l{l}.wo"] = dense((nq * dh, d))
        params[f"l{l}.mlp_norm"] = np.ones(d, np.float32)
        params[f"l{l}.w1"] = dense((d, cfg.d_ff))
        params[f"l{l}.w2"] = dense((cfg.d_ff, d))
    params["final_norm"] = np.ones(d, np.float32)
    params["lm_head"] = dense((d, cfg.vocab))
    return params


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope_angles(cfg: ModelConfig, positions: jnp.ndarray) -> jnp.ndarray:
    """[T, d_head/2] rotary angles for given positions."""
    half = cfg.d_head // 2
    freqs = cfg.rope_theta ** (-jnp.arange(half, dtype=jnp.float32) * 2.0 / cfg.d_head)
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(x: jnp.ndarray, ang: jnp.ndarray) -> jnp.ndarray:
    """Apply rotary embedding to x[..., d] with matching-rank angles [..., d/2].

    Pairs are (x[2i], x[2i+1]); rank of `ang` must broadcast against x's
    leading axes (e.g. x [T, H, d], ang [T, 1, d/2]).
    """
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    c, s = jnp.cos(ang), jnp.sin(ang)
    r1 = x1 * c - x2 * s
    r2 = x1 * s + x2 * c
    out = jnp.stack([r1, r2], axis=-1)
    return out.reshape(x.shape)


def mlp(x: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x @ w1) @ w2


# ---------------------------------------------------------------------------
# training / baseline forward (original weights, no rotation)
# ---------------------------------------------------------------------------

def dense_forward(params: Dict[str, jnp.ndarray], cfg: ModelConfig,
                  tokens: jnp.ndarray) -> jnp.ndarray:
    """Causal forward over tokens [T] -> logits [T, vocab]."""
    t = tokens.shape[0]
    dh, nq, nkv, g = cfg.d_head, cfg.n_q_heads, cfg.n_kv_heads, cfg.group
    h = params["embed"][tokens]                        # [T, d]
    ang = rope_angles(cfg, jnp.arange(t))[:, None, :]  # [T, 1, half]
    causal = jnp.tril(jnp.ones((t, t), jnp.float32))
    for l in range(cfg.n_layers):
        xn = rmsnorm(h, params[f"l{l}.attn_norm"])
        q = (xn @ params[f"l{l}.wq"]).reshape(t, nq, dh)
        k = (xn @ params[f"l{l}.wk"]).reshape(t, nkv, dh)
        v = (xn @ params[f"l{l}.wv"]).reshape(t, nkv, dh)
        q = apply_rope(q, ang)
        k = apply_rope(k, ang)
        kx = jnp.repeat(k, g, axis=1)                  # [T, nq, dh]
        vx = jnp.repeat(v, g, axis=1)
        s = jnp.einsum("thd,shd->hts", q, kx) / jnp.sqrt(jnp.float32(dh))
        s = jnp.where(causal[None] > 0, s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("hts,shd->thd", w, vx).reshape(t, nq * dh)
        h = h + o @ params[f"l{l}.wo"]
        h = h + mlp(rmsnorm(h, params[f"l{l}.mlp_norm"]),
                    params[f"l{l}.w1"], params[f"l{l}.w2"])
    return rmsnorm(h, params["final_norm"]) @ params["lm_head"]


# ---------------------------------------------------------------------------
# SWAN rotated-space graphs
# ---------------------------------------------------------------------------

def params_to_list(params: Dict[str, np.ndarray], names: List[str]) -> List[np.ndarray]:
    return [params[n] for n in names]


def list_to_params(flat: List[jnp.ndarray], names: List[str]) -> Dict[str, jnp.ndarray]:
    return dict(zip(names, flat))


def swan_prefill(sp: Dict[str, jnp.ndarray], cfg: ModelConfig, tokens: jnp.ndarray,
                 tmask: jnp.ndarray):
    """Prompt phase in rotated space.

    tokens [T] int32, tmask [T] f32 (1 = real token, 0 = right padding).
    Returns (logits_last [vocab], khat [L, n_kv, T, dh], vhat [L, n_kv, T, dh]).
    Rotation is lossless (Lemma A.1/A.2) so logits match the dense model.
    """
    t = tokens.shape[0]
    dh, nq, nkv, g = cfg.d_head, cfg.n_q_heads, cfg.n_kv_heads, cfg.group
    h = sp["embed"][tokens]
    ang = rope_angles(cfg, jnp.arange(t))[:, None, :]
    causal = jnp.tril(jnp.ones((t, t), jnp.float32)) * tmask[None, :]
    khats, vhats = [], []
    for l in range(cfg.n_layers):
        xn = rmsnorm(h, sp[f"l{l}.attn_norm"])
        q = (xn @ sp[f"l{l}.wq"]).reshape(t, nq, dh)
        k = (xn @ sp[f"l{l}.wk"]).reshape(t, nkv, dh)
        vhat = (xn @ sp[f"l{l}.wv_hat"]).reshape(t, nkv, dh)
        q = apply_rope(q, ang)
        k = apply_rope(k, ang)
        p = sp[f"l{l}.p_qk"]                           # [n_kv, dh, dh]
        # rotate: query head j uses its kv-group's projection
        qhat = jnp.einsum("thd,hde->the", q.reshape(t, nq, dh),
                          jnp.repeat(p, g, axis=0))
        khat = jnp.einsum("thd,hde->the", k, p)
        kx = jnp.repeat(khat, g, axis=1)
        vx = jnp.repeat(vhat, g, axis=1)
        s = jnp.einsum("thd,shd->hts", qhat, kx) / jnp.sqrt(jnp.float32(dh))
        s = jnp.where(causal[None] > 0, s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("hts,shd->thd", w, vx).reshape(t, nq * dh)
        h = h + o @ sp[f"l{l}.wo_hat"]
        h = h + mlp(rmsnorm(h, sp[f"l{l}.mlp_norm"]), sp[f"l{l}.w1"], sp[f"l{l}.w2"])
        khats.append(jnp.transpose(khat, (1, 0, 2)))   # [n_kv, T, dh]
        vhats.append(jnp.transpose(vhat, (1, 0, 2)))
    last = jnp.maximum(jnp.sum(tmask).astype(jnp.int32) - 1, 0)
    logits = rmsnorm(h[last], sp["final_norm"]) @ sp["lm_head"]
    return logits, jnp.stack(khats), jnp.stack(vhats)


def swan_decode_step(sp: Dict[str, jnp.ndarray], cfg: ModelConfig,
                     token: jnp.ndarray, pos: jnp.ndarray,
                     sp_kvals: jnp.ndarray, sp_kidx: jnp.ndarray,
                     sp_vvals: jnp.ndarray, sp_vidx: jnp.ndarray,
                     kbuf: jnp.ndarray, vbuf: jnp.ndarray,
                     smask: jnp.ndarray, bmask: jnp.ndarray):
    """One decode step over the hybrid cache (Algorithm 1).

    token, pos: i32 scalars.
    sp_* : [L, n_kv, Ls, k] (f32 / i32) — winnowed historical cache.
    kbuf/vbuf: [L, n_kv, B, dh] — dense recency buffers.
    smask [Ls], bmask [B] — validity masks (shared across layers).
    Returns (logits [vocab], khat [L, n_kv, dh], vhat [L, n_kv, dh]).
    The *current* token attends to itself via a virtual buffer row appended
    inside the graph; the rust side appends it to the real buffer after the
    call.
    """
    dh, nq, nkv, g = cfg.d_head, cfg.n_q_heads, cfg.n_kv_heads, cfg.group
    h = sp["embed"][token]
    ang = rope_angles(cfg, pos[None])[0][None, :]      # [1, half]
    khats, vhats = [], []
    bmask_eff = jnp.concatenate([bmask, jnp.ones((1,), jnp.float32)])
    for l in range(cfg.n_layers):
        xn = rmsnorm(h, sp[f"l{l}.attn_norm"])
        q = (xn @ sp[f"l{l}.wq"]).reshape(nq, dh)
        k = (xn @ sp[f"l{l}.wk"]).reshape(nkv, dh)
        vhat = (xn @ sp[f"l{l}.wv_hat"]).reshape(nkv, dh)
        q = apply_rope(q, ang)
        k = apply_rope(k, ang)
        p = sp[f"l{l}.p_qk"]                           # [n_kv, dh, dh]
        # L1 rotate kernel: queries grouped per kv head, keys per kv head
        qhat = jnp.stack([rotate(q[j][None], p[j // g])[0] for j in range(nq)])
        khat = jnp.stack([rotate(k[i][None], p[i])[0] for i in range(nkv)])
        outs = []
        for j in range(nq):
            grp = j // g
            kb = jnp.concatenate([kbuf[l, grp], khat[grp][None]], axis=0)
            vb = jnp.concatenate([vbuf[l, grp], vhat[grp][None]], axis=0)
            outs.append(swan_attention(
                qhat[j],
                sp_kvals[l, grp], sp_kidx[l, grp],
                sp_vvals[l, grp], sp_vidx[l, grp],
                kb, vb, smask, bmask_eff))
        o = jnp.concatenate(outs)                      # [nq*dh]
        h = h + o @ sp[f"l{l}.wo_hat"]
        h = h + mlp(rmsnorm(h, sp[f"l{l}.mlp_norm"]), sp[f"l{l}.w1"], sp[f"l{l}.w2"])
        khats.append(khat)
        vhats.append(vhat)
    logits = rmsnorm(h, sp["final_norm"]) @ sp["lm_head"]
    return logits, jnp.stack(khats), jnp.stack(vhats)


def dense_decode_step(sp: Dict[str, jnp.ndarray], cfg: ModelConfig,
                      token: jnp.ndarray, pos: jnp.ndarray,
                      kcache: jnp.ndarray, vcache: jnp.ndarray,
                      cmask: jnp.ndarray):
    """Baseline decode step over a dense rotated cache [L, n_kv, Lmax, dh].

    Because rotation is lossless, this is numerically the uncompressed
    model — it is the serving-mode baseline the paper compares against.
    """
    dh, nq, nkv, g = cfg.d_head, cfg.n_q_heads, cfg.n_kv_heads, cfg.group
    h = sp["embed"][token]
    ang = rope_angles(cfg, pos[None])[0][None, :]
    khats, vhats = [], []
    cmask_eff = jnp.concatenate([cmask, jnp.ones((1,), jnp.float32)])
    for l in range(cfg.n_layers):
        xn = rmsnorm(h, sp[f"l{l}.attn_norm"])
        q = (xn @ sp[f"l{l}.wq"]).reshape(nq, dh)
        k = (xn @ sp[f"l{l}.wk"]).reshape(nkv, dh)
        vhat = (xn @ sp[f"l{l}.wv_hat"]).reshape(nkv, dh)
        q = apply_rope(q, ang)
        k = apply_rope(k, ang)
        p = sp[f"l{l}.p_qk"]
        qhat = jnp.einsum("hd,hde->he", q, jnp.repeat(p, g, axis=0))
        khat = jnp.einsum("hd,hde->he", k, p)
        outs = []
        for j in range(nq):
            grp = j // g
            kc = jnp.concatenate([kcache[l, grp], khat[grp][None]], axis=0)
            vc = jnp.concatenate([vcache[l, grp], vhat[grp][None]], axis=0)
            s = (kc @ qhat[j]) / jnp.sqrt(jnp.float32(dh))
            s = jnp.where(cmask_eff > 0, s, NEG_INF)
            w = jax.nn.softmax(s)
            outs.append(w @ vc)
        o = jnp.concatenate(outs)
        h = h + o @ sp[f"l{l}.wo_hat"]
        h = h + mlp(rmsnorm(h, sp[f"l{l}.mlp_norm"]), sp[f"l{l}.w1"], sp[f"l{l}.w2"])
        khats.append(khat)
        vhats.append(vhat)
    logits = rmsnorm(h, sp["final_norm"]) @ sp["lm_head"]
    return logits, jnp.stack(khats), jnp.stack(vhats)
