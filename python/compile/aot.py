"""AOT pipeline: train -> calibrate -> absorb -> lower to HLO text.

Emits into ``artifacts/``:

  * ``weights_<model>.bin``      — original + absorbed weights + projections
  * ``golden_<model>.bin``       — reference activations for rust verification
  * ``<model>__<graph>.hlo.txt`` — AOT graphs (prefill / swan decode / dense
                                   decode / prune), weights as HLO parameters
  * ``model.hlo.txt``            — tiny smoke graph for the runtime self-test
  * ``manifest.json``            — graph/arg/shape index for the rust runtime
  * ``train_log_<model>.txt``    — loss curves (recorded in EXPERIMENTS.md)

HLO **text** is the interchange format (not serialized protos): jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids and round-trips cleanly.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import calibrate, common, corpus, model, train
from .common import ModelConfig
from .kernels.topk_prune import topk_prune
from .kernels.swan_attention import swan_attention

PREFILL_T = [64, 128, 256]
DECODE_L = [128, 256, 512]
DECODE_K = [16, 32, 48]
PRUNE_N = [256]
BUF = 64          # dense-buffer rows in the AOT serving graphs
DENSE_L = 512     # dense-baseline cache bucket


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _arg_meta(name, spec):
    return {"name": name, "shape": list(spec.shape), "dtype": str(np.dtype(spec.dtype))}


def param_specs(params: dict, names: list) -> list:
    return [_spec(params[n].shape, params[n].dtype) for n in names]


def lower_model_graphs(cfg: ModelConfig, sp: dict, out_dir: str) -> dict:
    """Lower all serving graphs for one model; returns manifest entries."""
    names = common.swan_param_names(cfg)
    pspecs = param_specs(sp, names)
    nl, nkv, dh, vocab = cfg.n_layers, cfg.n_kv_heads, cfg.d_head, cfg.vocab
    graphs = {}

    def emit(graph_name, fn, runtime_args):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*pspecs, *[s for _, s in runtime_args])
        text = to_hlo_text(lowered)
        fname = f"{cfg.name}__{graph_name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        graphs[graph_name] = {
            "file": fname,
            "param_names": names,
            "args": [_arg_meta(n, s) for n, s in runtime_args],
        }
        print(f"  lowered {fname} ({len(text)//1024} KiB, {time.time()-t0:.1f}s)",
              flush=True)

    # ---- prefill buckets ----
    for t in PREFILL_T:
        def prefill_fn(*flat, _t=t):
            p = model.list_to_params(list(flat[: len(names)]), names)
            tokens, tmask = flat[len(names):]
            return model.swan_prefill(p, cfg, tokens, tmask)
        emit(f"prefill_t{t}", prefill_fn,
             [("tokens", _spec((t,), jnp.int32)), ("tmask", _spec((t,)))])

    # ---- swan hybrid decode buckets ----
    for ls in DECODE_L:
        for k in DECODE_K:
            def decode_fn(*flat):
                p = model.list_to_params(list(flat[: len(names)]), names)
                (token, pos, kvals, kidx, vvals, vidx, kbuf, vbuf,
                 smask, bmask) = flat[len(names):]
                return model.swan_decode_step(p, cfg, token, pos, kvals, kidx,
                                              vvals, vidx, kbuf, vbuf, smask, bmask)
            emit(f"decode_l{ls}_k{k}", decode_fn, [
                ("token", _spec((), jnp.int32)),
                ("pos", _spec((), jnp.int32)),
                ("sp_kvals", _spec((nl, nkv, ls, k))),
                ("sp_kidx", _spec((nl, nkv, ls, k), jnp.int32)),
                ("sp_vvals", _spec((nl, nkv, ls, k))),
                ("sp_vidx", _spec((nl, nkv, ls, k), jnp.int32)),
                ("kbuf", _spec((nl, nkv, BUF, dh))),
                ("vbuf", _spec((nl, nkv, BUF, dh))),
                ("smask", _spec((ls,))),
                ("bmask", _spec((BUF,))),
            ])

    # ---- dense baseline decode ----
    def dense_fn(*flat):
        p = model.list_to_params(list(flat[: len(names)]), names)
        token, pos, kc, vc, cmask = flat[len(names):]
        return model.dense_decode_step(p, cfg, token, pos, kc, vc, cmask)
    emit(f"decode_dense_l{DENSE_L}", dense_fn, [
        ("token", _spec((), jnp.int32)),
        ("pos", _spec((), jnp.int32)),
        ("kcache", _spec((nl, nkv, DENSE_L, dh))),
        ("vcache", _spec((nl, nkv, DENSE_L, dh))),
        ("cmask", _spec((DENSE_L,))),
    ])
    return graphs


def lower_prune_graphs(dh: int, out_dir: str) -> dict:
    graphs = {}
    for n in PRUNE_N:
        for k in DECODE_K:
            lowered = jax.jit(lambda x, _k=k: topk_prune(x, _k)).lower(
                _spec((n, dh)))
            fname = f"prune_n{n}_k{k}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(to_hlo_text(lowered))
            graphs[f"prune_n{n}_k{k}"] = {
                "file": fname, "param_names": [],
                "args": [_arg_meta("x", _spec((n, dh)))],
            }
    return graphs


def write_smoke_graph(out_dir: str) -> None:
    """Tiny single-head swan-attention graph for the runtime self-test."""
    d, ls, k, b = 8, 4, 2, 3
    lowered = jax.jit(swan_attention).lower(
        _spec((d,)), _spec((ls, k)), _spec((ls, k), jnp.int32),
        _spec((ls, k)), _spec((ls, k), jnp.int32),
        _spec((b, d)), _spec((b, d)), _spec((ls,)), _spec((b,)))
    with open(os.path.join(out_dir, "model.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))


def make_goldens(cfg: ModelConfig, params: dict, sp: dict) -> dict:
    """Reference activations for rust-side model verification."""
    t = 48
    text = corpus.generate_text(4 * t, seed=99)
    tokens = common.encode_text(text)[:t]
    tmask = np.ones(t, np.float32)

    jp = {k: jnp.asarray(v) for k, v in params.items()}
    jsp = {k: jnp.asarray(v) for k, v in sp.items()}
    dense_logits = np.asarray(model.dense_forward(jp, cfg, jnp.asarray(tokens)))
    pf_logits, khat, vhat = model.swan_prefill(jsp, cfg, jnp.asarray(tokens),
                                               jnp.asarray(tmask))

    # one dense decode step after the prefill
    nl, nkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
    lmax = 64
    kc = np.zeros((nl, nkv, lmax, dh), np.float32)
    vc = np.zeros((nl, nkv, lmax, dh), np.float32)
    kc[:, :, :t] = np.asarray(khat)
    vc[:, :, :t] = np.asarray(vhat)
    cmask = np.zeros(lmax, np.float32)
    cmask[:t] = 1.0
    next_tok = int(np.argmax(np.asarray(pf_logits)))
    dd_logits, dk, dv = model.dense_decode_step(
        jsp, cfg, jnp.int32(next_tok), jnp.int32(t),
        jnp.asarray(kc), jnp.asarray(vc), jnp.asarray(cmask))

    # one swan hybrid decode step: buffer = last 16 tokens, rest pruned k=32
    buf_n, k_active, ls = 16, 32, 64
    kbuf = np.zeros((nl, nkv, buf_n, dh), np.float32)
    vbuf = np.zeros((nl, nkv, buf_n, dh), np.float32)
    kbuf[:, :, : buf_n] = np.asarray(khat)[:, :, t - buf_n : t]
    vbuf[:, :, : buf_n] = np.asarray(vhat)[:, :, t - buf_n : t]
    n_sp = t - buf_n
    kvals = np.zeros((nl, nkv, ls, k_active), np.float32)
    kidx = np.zeros((nl, nkv, ls, k_active), np.int32)
    vvals = np.zeros((nl, nkv, ls, k_active), np.float32)
    vidx = np.zeros((nl, nkv, ls, k_active), np.int32)
    for l in range(nl):
        for hd in range(nkv):
            kv, ki = topk_prune(jnp.asarray(khat)[l, hd, :n_sp], k_active)
            vv, vi = topk_prune(jnp.asarray(vhat)[l, hd, :n_sp], k_active)
            kvals[l, hd, :n_sp] = np.asarray(kv)
            kidx[l, hd, :n_sp] = np.asarray(ki)
            vvals[l, hd, :n_sp] = np.asarray(vv)
            vidx[l, hd, :n_sp] = np.asarray(vi)
    smask = np.zeros(ls, np.float32); smask[:n_sp] = 1.0
    bmask = np.ones(buf_n, np.float32)
    sw_logits, swk, swv = model.swan_decode_step(
        jsp, cfg, jnp.int32(next_tok), jnp.int32(t),
        jnp.asarray(kvals), jnp.asarray(kidx), jnp.asarray(vvals),
        jnp.asarray(vidx), jnp.asarray(kbuf), jnp.asarray(vbuf),
        jnp.asarray(smask), jnp.asarray(bmask))

    return {
        "prompt_tokens": tokens.astype(np.int32),
        "dense_logits": dense_logits,
        "prefill_logits": np.asarray(pf_logits),
        "prefill_khat": np.asarray(khat),
        "prefill_vhat": np.asarray(vhat),
        "dense_decode_logits": np.asarray(dd_logits),
        "dense_decode_khat": np.asarray(dk),
        "dense_decode_vhat": np.asarray(dv),
        "swan_decode_logits": np.asarray(sw_logits),
        "swan_decode_token": np.asarray([next_tok], np.int32),
        "swan_decode_cfg": np.asarray([buf_n, k_active, ls, t], np.int32),
    }


def build_model(cfg: ModelConfig, out_dir: str, steps: int, force: bool) -> dict:
    wpath = os.path.join(out_dir, f"weights_{cfg.name}.bin")
    if os.path.exists(wpath) and not force:
        print(f"[aot] reusing {wpath}")
        meta, tensors = common.read_tensors(wpath)
        params = {n: tensors[n] for n in common.param_names(cfg)}
        sp = {n: tensors[n] for n in common.swan_param_names(cfg)}
        for l in range(cfg.n_layers):
            sp[f"l{l}.p_vo"] = tensors[f"l{l}.p_vo"]
    else:
        print(f"[aot] training {cfg.name} ({steps} steps)")
        params, log = train.train(cfg, steps=steps)
        with open(os.path.join(out_dir, f"train_log_{cfg.name}.txt"), "w") as f:
            for s, l in log:
                f.write(f"{s}\t{l:.6f}\n")
        print(f"[aot] calibrating {cfg.name}")
        p_qk, p_vo = calibrate.compute_projections(params, cfg)
        sp = calibrate.absorb_weights(params, cfg, p_qk, p_vo)
        tensors = dict(params)
        tensors.update(sp)
        common.write_tensors(wpath, json.loads(cfg.to_json()), tensors)
        print(f"[aot] wrote {wpath} ({os.path.getsize(wpath)//1024} KiB)")

    gpath = os.path.join(out_dir, f"golden_{cfg.name}.bin")
    if not os.path.exists(gpath) or force:
        goldens = make_goldens(cfg, params, sp)
        common.write_tensors(gpath, json.loads(cfg.to_json()), goldens)
        print(f"[aot] wrote {gpath}")

    print(f"[aot] lowering graphs for {cfg.name}")
    graphs = lower_model_graphs(cfg, sp, out_dir)
    return {
        "config": json.loads(cfg.to_json()),
        "weights": f"weights_{cfg.name}.bin",
        "golden": f"golden_{cfg.name}.bin",
        "buf": BUF,
        "graphs": graphs,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="compat: path to model.hlo.txt")
    ap.add_argument("--steps", type=int,
                    default=int(os.environ.get("SWAN_TRAIN_STEPS", "400")))
    ap.add_argument("--models", default="swan-nano-gqa,swan-nano-mha")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    out_dir = out_dir or "."
    os.makedirs(out_dir, exist_ok=True)

    manifest = {"version": 1, "buf": BUF, "decode_l": DECODE_L,
                "decode_k": DECODE_K, "prefill_t": PREFILL_T, "models": {}}
    for name in args.models.split(","):
        cfg = common.CONFIGS[name.strip()]
        manifest["models"][cfg.name] = build_model(cfg, out_dir, args.steps,
                                                   args.force)
    manifest["prune_graphs"] = lower_prune_graphs(64, out_dir)
    write_smoke_graph(out_dir)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("[aot] manifest written; artifacts complete")


if __name__ == "__main__":
    main()
