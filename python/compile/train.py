"""Build-time training of the swan-nano models on the synthetic corpus.

Runs once inside ``make artifacts`` (python is never on the request path).
A hand-rolled Adam is used (optax is not available in the sandbox).  The
loss curve is written next to the weights so EXPERIMENTS.md can record the
end-to-end training evidence.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import common, corpus, model
from .common import ModelConfig

SEQ_LEN = 320
BATCH = 12


def make_batches(text_ids: np.ndarray, n_steps: int, seed: int):
    """Yield [BATCH, SEQ_LEN+1] windows sampled uniformly from the corpus."""
    rng = np.random.default_rng(seed)
    hi = len(text_ids) - SEQ_LEN - 1
    for _ in range(n_steps):
        starts = rng.integers(0, hi, size=BATCH)
        yield np.stack([text_ids[s : s + SEQ_LEN + 1] for s in starts])


def loss_fn(params, cfg: ModelConfig, batch: jnp.ndarray) -> jnp.ndarray:
    tokens, targets = batch[:, :-1], batch[:, 1:]
    logits = jax.vmap(lambda t: model.dense_forward(params, cfg, t))(tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return zeros, jax.tree_util.tree_map(jnp.zeros_like, params)


def adam_update(params, grads, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = jax.tree_util.tree_map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
    v = jax.tree_util.tree_map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
    mh = jax.tree_util.tree_map(lambda a: a / (1 - b1 ** step), m)
    vh = jax.tree_util.tree_map(lambda a: a / (1 - b2 ** step), v)
    params = jax.tree_util.tree_map(
        lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps), params, mh, vh)
    return params, m, v


def train(cfg: ModelConfig, steps: int = 400, seed: int = 0,
          lr: float = 3e-3, log_every: int = 25) -> Tuple[Dict[str, np.ndarray], List[Tuple[int, float]]]:
    """Train and return (params, loss_log)."""
    text = corpus.generate_text(400_000, seed=seed + 7)
    ids = common.encode_text(text)
    params = {k: jnp.asarray(v) for k, v in model.init_params(cfg, seed).items()}
    m, v = adam_init(params)

    @jax.jit
    def step_fn(params, m, v, batch, step):
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
        # cosine decay with short warmup
        warm = jnp.minimum(step / 20.0, 1.0)
        decay = 0.5 * (1 + jnp.cos(jnp.pi * jnp.minimum(step / steps, 1.0)))
        cur_lr = lr * warm * (0.1 + 0.9 * decay)
        params, m, v = adam_update(params, grads, m, v, step, cur_lr)
        return params, m, v, loss

    log: List[Tuple[int, float]] = []
    t0 = time.time()
    for i, batch in enumerate(make_batches(ids, steps, seed), start=1):
        params, m, v, loss = step_fn(params, m, v, jnp.asarray(batch), jnp.float32(i))
        if i % log_every == 0 or i == 1 or i == steps:
            l = float(loss)
            log.append((i, l))
            print(f"[train {cfg.name}] step {i}/{steps} loss {l:.4f} "
                  f"({time.time()-t0:.0f}s)", flush=True)
    return {k: np.asarray(v) for k, v in params.items()}, log
