"""Deterministic synthetic corpus for training + calibration.

Substitutes the paper's natural-language training/calibration data
(BookCorpus) with a mixture of five content families that exercise the same
properties the paper's evaluation probes:

  * patterned prose        -> generic language-model signal
  * key/value facts        -> factual recall (MMLU/ARC analogue)
  * arithmetic chains      -> multi-step reasoning (GSM8K analogue)
  * code-like definitions  -> code completion (LCC analogue)
  * passkey sentences      -> long-context retrieval (LongBench analogue)

Everything is produced by a PCG-64 generator seeded deterministically so
training is reproducible.  The rust evaluation harness
(`rust/src/eval/corpus.rs`) implements the same grammar (it does not need
bit-identical streams — only the same distribution and alphabet).
"""

from __future__ import annotations

import numpy as np

ADJS = ["quick", "sparse", "dense", "rotated", "pruned", "long", "short", "hidden", "salient", "quiet"]
NOUNS = ["cache", "vector", "token", "model", "matrix", "buffer", "kernel", "query", "key", "value"]
VERBS = ["stores", "rotates", "prunes", "reads", "writes", "scans", "maps", "folds", "splits", "joins"]


def _prose(rng: np.random.Generator) -> str:
    return (
        f"the {rng.choice(ADJS)} {rng.choice(NOUNS)} {rng.choice(VERBS)} "
        f"the {rng.choice(ADJS)} {rng.choice(NOUNS)} . "
    )


def _filler(rng: np.random.Generator, n_chars: int) -> str:
    out = []
    total = 0
    while total < n_chars:
        s = _prose(rng)
        out.append(s)
        total += len(s)
    return "".join(out)[:n_chars].rsplit(" ", 1)[0] + " "


def _fact(rng: np.random.Generator) -> str:
    """Fact declaration and recall separated by a random-length gap so the
    model learns genuine long-range retrieval (the paper's benchmarks all
    probe recall of mid-context tokens)."""
    key = f"{rng.choice(NOUNS)}{rng.integers(0, 100)}"
    val = int(rng.integers(0, 1000))
    gap = _filler(rng, int(rng.integers(0, 160)))
    return f"fact {key} is {val} . {gap}recall {key} -> {val} . "


def _arith(rng: np.random.Generator, steps: int = 4) -> str:
    x = int(rng.integers(1, 50))
    parts = [f"start {x} ;"]
    for _ in range(steps):
        d = int(rng.integers(1, 10))
        if rng.random() < 0.5:
            x += d
            parts.append(f"add {d} = {x} ;")
        else:
            x -= d
            parts.append(f"sub {d} = {x} ;")
    parts.append(f"answer {x} . ")
    return " ".join(parts)


def _code(rng: np.random.Generator) -> str:
    i = int(rng.integers(0, 100))
    n = int(rng.integers(1, 20))
    op = rng.choice(["+", "-", "*"])
    return f"def f{i}(x): return x {op} {n} ; f{i}({n}) ; "


def _passkey(rng: np.random.Generator) -> str:
    """Passkey retrieval across a log-uniform 10..260-char gap — trains the
    long-context retrieval behaviour LongBench-style tasks evaluate."""
    key = "".join(str(rng.integers(0, 10)) for _ in range(5))
    gap = int(np.exp(rng.uniform(np.log(10), np.log(260))))
    filler = _filler(rng, gap)
    return f"the passkey is {key} . {filler}. the passkey was {key} . "


_FAMILIES = [_prose, _fact, _arith, _code, _passkey]
_WEIGHTS = np.array([0.35, 0.2, 0.2, 0.15, 0.1])


def generate_text(n_chars: int, seed: int = 0) -> str:
    """Generate at least `n_chars` characters of corpus text."""
    rng = np.random.default_rng(seed)
    chunks = []
    total = 0
    while total < n_chars:
        fam = rng.choice(len(_FAMILIES), p=_WEIGHTS)
        s = _FAMILIES[fam](rng)
        chunks.append(s)
        total += len(s)
    return "".join(chunks)[:n_chars]
