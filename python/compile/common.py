"""Shared configuration, parameter containers and binary tensor I/O.

The binary tensor container (`write_tensors` / `read_tensors`) is the
interchange format between the build-time python side and the rust runtime
(`rust/src/model/weights.rs` implements the mirror reader/writer).

Layout (little endian):
    magic   8 bytes  b"SWANWTS1"
    meta    u32 json_len + utf-8 json blob (model hyper-parameters)
    count   u32 number of tensors
    tensor* repeated:
        u16  name_len, name bytes (utf-8)
        u8   dtype  (0 = f32, 1 = i32)
        u8   ndim
        u32  dims[ndim]
        raw  little-endian data (prod(dims) * 4 bytes)
"""

from __future__ import annotations

import dataclasses
import json
import struct
from typing import Dict, Tuple

import numpy as np

MAGIC = b"SWANWTS1"

# Character-level tokenizer: ids 0..95 map to ASCII 32..127 (' ' .. '~').
VOCAB_SIZE = 96
CHAR_BASE = 32


def encode_text(s: str) -> np.ndarray:
    """Map a string to token ids; characters outside the alphabet become ' '."""
    ids = np.frombuffer(s.encode("ascii", errors="replace"), dtype=np.uint8).astype(np.int32)
    ids = ids - CHAR_BASE
    ids = np.where((ids < 0) | (ids >= VOCAB_SIZE), 0, ids)
    return ids


def decode_ids(ids) -> str:
    return "".join(chr(int(i) + CHAR_BASE) for i in ids)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyper-parameters of a swan-nano model variant."""

    name: str
    d_model: int = 256
    n_layers: int = 4
    n_q_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 64
    d_ff: int = 1024
    vocab: int = VOCAB_SIZE
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def group(self) -> int:
        assert self.n_q_heads % self.n_kv_heads == 0
        return self.n_q_heads // self.n_kv_heads

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @staticmethod
    def from_json(s: str) -> "ModelConfig":
        return ModelConfig(**json.loads(s))


#: The two architectures evaluated in the paper (Fig. 3/5): a GQA model
#: (Llama-3.1 analogue) and an MHA model (OLMoE analogue).
NANO_GQA = ModelConfig(name="swan-nano-gqa", n_q_heads=4, n_kv_heads=1)
NANO_MHA = ModelConfig(name="swan-nano-mha", n_q_heads=4, n_kv_heads=4)

CONFIGS = {c.name: c for c in (NANO_GQA, NANO_MHA)}

_DTYPES = {0: np.float32, 1: np.int32}
_DTYPE_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def write_tensors(path: str, meta: dict, tensors: Dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        blob = json.dumps(meta).encode("utf-8")
        f.write(struct.pack("<I", len(blob)))
        f.write(blob)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _DTYPE_CODES:
                if np.issubdtype(arr.dtype, np.floating):
                    arr = arr.astype(np.float32)
                else:
                    arr = arr.astype(np.int32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPE_CODES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_tensors(path: str) -> Tuple[dict, Dict[str, np.ndarray]]:
    with open(path, "rb") as f:
        assert f.read(8) == MAGIC, "bad magic"
        (jlen,) = struct.unpack("<I", f.read(4))
        meta = json.loads(f.read(jlen).decode("utf-8"))
        (count,) = struct.unpack("<I", f.read(4))
        out: Dict[str, np.ndarray] = {}
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            dtype_code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack("<" + "I" * ndim, f.read(4 * ndim))
            dt = _DTYPES[dtype_code]
            n = int(np.prod(dims)) if ndim else 1
            out[name] = np.frombuffer(f.read(4 * n), dtype=dt).reshape(dims).copy()
        return meta, out


def param_names(cfg: ModelConfig) -> list:
    """Deterministic flat ordering of model parameters.

    This ordering defines the HLO parameter order for AOT graphs and the
    buffer order the rust runtime feeds to `execute_b`.
    """
    names = ["embed"]
    for l in range(cfg.n_layers):
        names += [
            f"l{l}.attn_norm",
            f"l{l}.wq",
            f"l{l}.wk",
            f"l{l}.wv",
            f"l{l}.wo",
            f"l{l}.mlp_norm",
            f"l{l}.w1",
            f"l{l}.w2",
        ]
    names += ["final_norm", "lm_head"]
    return names


def swan_param_names(cfg: ModelConfig) -> list:
    """Parameter ordering for SWAN graphs: absorbed weights + projections."""
    names = ["embed"]
    for l in range(cfg.n_layers):
        names += [
            f"l{l}.attn_norm",
            f"l{l}.wq",
            f"l{l}.wk",
            f"l{l}.wv_hat",
            f"l{l}.wo_hat",
            f"l{l}.p_qk",
            f"l{l}.mlp_norm",
            f"l{l}.w1",
            f"l{l}.w2",
        ]
    names += ["final_norm", "lm_head"]
    return names
