"""Offline calibration: joint-subspace SVD projections (§4.1) and weight
absorption (§4.2).

For each layer l and kv-head group j we build

    S_QK = Concat(Q_grouped, K)          (post-RoPE activations)
    S_VO = Concat(V, W_O_grouped^T)

and take the right-singular basis V of each as the projection matrices
P_QK / P_VO.  P_VO is absorbed into Ŵ_V = W_V P_VO and
Ŵ_O = P_VO^T W_O (per head slice); P_QK must be applied at runtime
because RoPE does not commute with a static rotation.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import common, corpus, model
from .common import ModelConfig

CALIB_SEQS = 8
CALIB_LEN = 256


def collect_activations(params: Dict[str, np.ndarray], cfg: ModelConfig,
                        token_batches: np.ndarray):
    """Run the dense model and harvest post-RoPE Q/K and V per layer.

    token_batches: [N, T] int32.  Returns lists over layers of
    (Q [N*T, nq, dh], K [N*T, nkv, dh], V [N*T, nkv, dh]).
    """
    p = {k: jnp.asarray(v) for k, v in params.items()}
    dh, nq, nkv, g = cfg.d_head, cfg.n_q_heads, cfg.n_kv_heads, cfg.group

    @jax.jit
    def run(tokens):
        t = tokens.shape[0]
        h = p["embed"][tokens]
        ang = model.rope_angles(cfg, jnp.arange(t))[:, None, :]
        causal = jnp.tril(jnp.ones((t, t), jnp.float32))
        qs, ks, vs = [], [], []
        for l in range(cfg.n_layers):
            xn = model.rmsnorm(h, p[f"l{l}.attn_norm"])
            q = (xn @ p[f"l{l}.wq"]).reshape(t, nq, dh)
            k = (xn @ p[f"l{l}.wk"]).reshape(t, nkv, dh)
            v = (xn @ p[f"l{l}.wv"]).reshape(t, nkv, dh)
            q = model.apply_rope(q, ang)
            k = model.apply_rope(k, ang)
            qs.append(q); ks.append(k); vs.append(v)
            kx = jnp.repeat(k, g, axis=1)
            vx = jnp.repeat(v, g, axis=1)
            s = jnp.einsum("thd,shd->hts", q, kx) / jnp.sqrt(jnp.float32(dh))
            s = jnp.where(causal[None] > 0, s, model.NEG_INF)
            w = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("hts,shd->thd", w, vx).reshape(t, nq * dh)
            h = h + o @ p[f"l{l}.wo"]
            h = h + model.mlp(model.rmsnorm(h, p[f"l{l}.mlp_norm"]),
                              p[f"l{l}.w1"], p[f"l{l}.w2"])
        return qs, ks, vs

    acc_q = [[] for _ in range(cfg.n_layers)]
    acc_k = [[] for _ in range(cfg.n_layers)]
    acc_v = [[] for _ in range(cfg.n_layers)]
    for row in token_batches:
        qs, ks, vs = run(jnp.asarray(row))
        for l in range(cfg.n_layers):
            acc_q[l].append(np.asarray(qs[l]))
            acc_k[l].append(np.asarray(ks[l]))
            acc_v[l].append(np.asarray(vs[l]))
    out = []
    for l in range(cfg.n_layers):
        out.append((np.concatenate(acc_q[l]), np.concatenate(acc_k[l]),
                    np.concatenate(acc_v[l])))
    return out


def joint_svd_basis(mat: np.ndarray) -> np.ndarray:
    """Right-singular basis V of `mat` [rows, d] -> [d, d] orthogonal."""
    _, _, vh = np.linalg.svd(mat.astype(np.float64), full_matrices=True)
    return vh.T.astype(np.float32)


def compute_projections(params: Dict[str, np.ndarray], cfg: ModelConfig,
                        seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Return (p_qk [L, n_kv, dh, dh], p_vo [L, n_kv, dh, dh])."""
    rng = np.random.default_rng(seed + 13)
    text = corpus.generate_text(CALIB_SEQS * CALIB_LEN * 4, seed=seed + 13)
    ids = common.encode_text(text)
    starts = rng.integers(0, len(ids) - CALIB_LEN - 1, size=CALIB_SEQS)
    batches = np.stack([ids[s : s + CALIB_LEN] for s in starts])

    acts = collect_activations(params, cfg, batches)
    dh, nq, nkv, g, d = cfg.d_head, cfg.n_q_heads, cfg.n_kv_heads, cfg.group, cfg.d_model
    p_qk = np.zeros((cfg.n_layers, nkv, dh, dh), np.float32)
    p_vo = np.zeros((cfg.n_layers, nkv, dh, dh), np.float32)
    for l, (q, k, v) in enumerate(acts):
        # group queries: [N, nq, dh] -> [nkv, N*G, dh]
        qg = q.transpose(1, 0, 2).reshape(nkv, -1, dh)
        kg = k.transpose(1, 0, 2)                      # [nkv, N, dh]
        vg = v.transpose(1, 0, 2)
        wo = params[f"l{l}.wo"].reshape(nq, dh, d)     # per-head slices
        for j in range(nkv):
            s_qk = np.concatenate([qg[j], kg[j]], axis=0)
            p_qk[l, j] = joint_svd_basis(s_qk)
            # W_O rows for this group, transposed to d_h-dim row vectors
            wo_grp = wo[j * g : (j + 1) * g]           # [G, dh, d]
            wo_rows = wo_grp.transpose(0, 2, 1).reshape(-1, dh)  # [G*d, dh]
            s_vo = np.concatenate([vg[j], wo_rows], axis=0)
            p_vo[l, j] = joint_svd_basis(s_vo)
    return p_qk, p_vo


def absorb_weights(params: Dict[str, np.ndarray], cfg: ModelConfig,
                   p_qk: np.ndarray, p_vo: np.ndarray) -> Dict[str, np.ndarray]:
    """Produce the SWAN parameter set (absorbed Ŵ_V / Ŵ_O + projections).

    Ŵ_V generates values directly in the rotated space; Ŵ_O undoes the
    rotation — both exactly (Lemma A.2), so the only approximation in SWAN
    is the subsequent pruning.
    """
    dh, nq, nkv, g, d = cfg.d_head, cfg.n_q_heads, cfg.n_kv_heads, cfg.group, cfg.d_model
    sp: Dict[str, np.ndarray] = {"embed": params["embed"],
                                 "final_norm": params["final_norm"],
                                 "lm_head": params["lm_head"]}
    for l in range(cfg.n_layers):
        sp[f"l{l}.attn_norm"] = params[f"l{l}.attn_norm"]
        sp[f"l{l}.mlp_norm"] = params[f"l{l}.mlp_norm"]
        sp[f"l{l}.wq"] = params[f"l{l}.wq"]
        sp[f"l{l}.wk"] = params[f"l{l}.wk"]
        sp[f"l{l}.w1"] = params[f"l{l}.w1"]
        sp[f"l{l}.w2"] = params[f"l{l}.w2"]
        sp[f"l{l}.p_qk"] = p_qk[l]
        sp[f"l{l}.p_vo"] = p_vo[l]
        # Ŵ_V: per kv-head block of columns
        wv = params[f"l{l}.wv"].reshape(d, nkv, dh)
        wv_hat = np.einsum("dhe,hef->dhf", wv, p_vo[l]).reshape(d, nkv * dh)
        sp[f"l{l}.wv_hat"] = wv_hat.astype(np.float32)
        # Ŵ_O: per q-head slice pre-multiplied by its group's P_VO^T
        wo = params[f"l{l}.wo"].reshape(nq, dh, d)
        wo_hat = np.stack([p_vo[l, j // g].T @ wo[j] for j in range(nq)])
        sp[f"l{l}.wo_hat"] = wo_hat.reshape(nq * dh, d).astype(np.float32)
    return sp
