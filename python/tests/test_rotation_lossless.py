# Lemma A.1 / A.2: the SWAN rotation is lossless before pruning.
import jax.numpy as jnp
import numpy as np
import pytest

from compile import calibrate, common, corpus, model


@pytest.fixture(scope="module")
def calibrated():
    """Small randomly-initialised GQA + MHA models with real calibration."""
    out = {}
    for cfg in (common.NANO_GQA, common.NANO_MHA):
        params = model.init_params(cfg, seed=1)
        p_qk, p_vo = calibrate.compute_projections(params, cfg, seed=1)
        sp = calibrate.absorb_weights(params, cfg, p_qk, p_vo)
        out[cfg.name] = (cfg, params, p_qk, p_vo, sp)
    return out


@pytest.mark.parametrize("name", ["swan-nano-gqa", "swan-nano-mha"])
def test_projections_are_orthogonal(calibrated, name):
    cfg, _, p_qk, p_vo, _ = calibrated[name]
    eye = np.eye(cfg.d_head)
    for l in range(cfg.n_layers):
        for j in range(cfg.n_kv_heads):
            np.testing.assert_allclose(p_qk[l, j] @ p_qk[l, j].T, eye, atol=1e-4)
            np.testing.assert_allclose(p_vo[l, j] @ p_vo[l, j].T, eye, atol=1e-4)


@pytest.mark.parametrize("name", ["swan-nano-gqa", "swan-nano-mha"])
def test_lemma_a1_scores_invariant(calibrated, name):
    """q K^T == (q P)(K P)^T for the calibrated P_QK."""
    cfg, _, p_qk, _, _ = calibrated[name]
    rng = np.random.default_rng(0)
    q = rng.normal(size=(cfg.d_head,)).astype(np.float32)
    kc = rng.normal(size=(10, cfg.d_head)).astype(np.float32)
    p = p_qk[0, 0]
    s = kc @ q
    s_rot = (kc @ p) @ (q @ p)
    np.testing.assert_allclose(s_rot, s, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("name", ["swan-nano-gqa", "swan-nano-mha"])
def test_lemma_a2_full_model_lossless(calibrated, name):
    """swan_prefill (rotated space, absorbed weights) reproduces the dense
    model's logits exactly (up to float32 noise) — the only approximation in
    SWAN is pruning."""
    cfg, params, _, _, sp = calibrated[name]
    t = 32
    tokens = common.encode_text(corpus.generate_text(200, seed=2))[:t]
    jp = {k: jnp.asarray(v) for k, v in params.items()}
    jsp = {k: jnp.asarray(v) for k, v in sp.items()}
    dense = np.asarray(model.dense_forward(jp, cfg, jnp.asarray(tokens)))
    pf, khat, vhat = model.swan_prefill(jsp, cfg, jnp.asarray(tokens),
                                        jnp.ones(t, jnp.float32))
    np.testing.assert_allclose(np.asarray(pf), dense[-1], rtol=5e-3, atol=5e-3)
    assert khat.shape == (cfg.n_layers, cfg.n_kv_heads, t, cfg.d_head)


@pytest.mark.parametrize("name", ["swan-nano-gqa"])
def test_swan_decode_full_retention_equals_dense_decode(calibrated, name):
    """Hybrid decode with k_active = d_h must equal the dense decode step."""
    cfg, _, _, _, sp = calibrated[name]
    from compile.kernels.topk_prune import topk_prune
    jsp = {k: jnp.asarray(v) for k, v in sp.items()}
    t, bufn, ls = 24, 8, 32
    dh, nl, nkv = cfg.d_head, cfg.n_layers, cfg.n_kv_heads
    tokens = common.encode_text(corpus.generate_text(120, seed=3))[:t]
    _, khat, vhat = model.swan_prefill(jsp, cfg, jnp.asarray(tokens),
                                       jnp.ones(t, jnp.float32))
    khat, vhat = np.asarray(khat), np.asarray(vhat)

    kc = np.zeros((nl, nkv, 32, dh), np.float32); kc[:, :, :t] = khat
    vc = np.zeros((nl, nkv, 32, dh), np.float32); vc[:, :, :t] = vhat
    cm = np.zeros(32, np.float32); cm[:t] = 1
    dl, _, _ = model.dense_decode_step(jsp, cfg, jnp.int32(7), jnp.int32(t),
                                       jnp.asarray(kc), jnp.asarray(vc),
                                       jnp.asarray(cm))

    nsp = t - bufn
    kbuf = khat[:, :, nsp:t]; vbuf = vhat[:, :, nsp:t]
    kvals = np.zeros((nl, nkv, ls, dh), np.float32); kidx = np.zeros((nl, nkv, ls, dh), np.int32)
    vvals = np.zeros((nl, nkv, ls, dh), np.float32); vidx = np.zeros((nl, nkv, ls, dh), np.int32)
    for l in range(nl):
        for h in range(nkv):
            kv, ki = topk_prune(jnp.asarray(khat[l, h, :nsp]), dh)
            vv, vi = topk_prune(jnp.asarray(vhat[l, h, :nsp]), dh)
            kvals[l, h, :nsp] = kv; kidx[l, h, :nsp] = ki
            vvals[l, h, :nsp] = vv; vidx[l, h, :nsp] = vi
    sm = np.zeros(ls, np.float32); sm[:nsp] = 1
    sl, _, _ = model.swan_decode_step(
        jsp, cfg, jnp.int32(7), jnp.int32(t),
        *map(jnp.asarray, [kvals, kidx, vvals, vidx, kbuf, vbuf, sm,
                           np.ones(bufn, np.float32)]))
    np.testing.assert_allclose(np.asarray(sl), np.asarray(dl),
                               rtol=5e-3, atol=5e-3)
