# Calibration pipeline: shapes, groupings, SVD properties, absorption algebra.
import numpy as np
import pytest

from compile import calibrate, common, model


@pytest.fixture(scope="module")
def gqa_setup():
    cfg = common.NANO_GQA
    params = model.init_params(cfg, seed=4)
    return cfg, params


def test_joint_svd_basis_properties():
    rng = np.random.default_rng(0)
    m = rng.normal(size=(200, 16)).astype(np.float32)
    v = calibrate.joint_svd_basis(m)
    assert v.shape == (16, 16)
    np.testing.assert_allclose(v @ v.T, np.eye(16), atol=1e-5)
    # energy concentration: leading dims carry descending variance of m @ v
    proj = m @ v
    var = proj.var(axis=0)
    assert (np.diff(var) <= 1e-3).all(), "variance must be (weakly) descending"


def test_joint_svd_concentrates_lowrank_signal():
    """A matrix with planted rank-4 structure should concentrate >90% energy
    in the first 4 rotated dims."""
    rng = np.random.default_rng(1)
    basis = rng.normal(size=(4, 32))
    m = (rng.normal(size=(500, 4)) @ basis + 0.01 * rng.normal(size=(500, 32)))
    v = calibrate.joint_svd_basis(m.astype(np.float32))
    proj = m @ v
    energy = (proj ** 2).sum(axis=0)
    assert energy[:4].sum() / energy.sum() > 0.9


def test_collect_activations_shapes(gqa_setup):
    cfg, params = gqa_setup
    batches = np.zeros((2, 16), np.int32)
    acts = calibrate.collect_activations(params, cfg, batches)
    assert len(acts) == cfg.n_layers
    q, k, v = acts[0]
    assert q.shape == (32, cfg.n_q_heads, cfg.d_head)
    assert k.shape == (32, cfg.n_kv_heads, cfg.d_head)
    assert v.shape == (32, cfg.n_kv_heads, cfg.d_head)


def test_compute_projections_shapes(gqa_setup):
    cfg, params = gqa_setup
    p_qk, p_vo = calibrate.compute_projections(params, cfg, seed=4)
    assert p_qk.shape == (cfg.n_layers, cfg.n_kv_heads, cfg.d_head, cfg.d_head)
    assert p_vo.shape == p_qk.shape


def test_absorption_algebra(gqa_setup):
    """Ŵ_V = W_V P_VO and Ŵ_O = P_VO^T W_O per head slice, exactly."""
    cfg, params = gqa_setup
    rng = np.random.default_rng(2)
    dh, nkv, nq, g, d = cfg.d_head, cfg.n_kv_heads, cfg.n_q_heads, cfg.group, cfg.d_model
    p_vo = np.stack([
        np.stack([np.linalg.qr(rng.normal(size=(dh, dh)))[0].astype(np.float32)
                  for _ in range(nkv)])
        for _ in range(cfg.n_layers)])
    p_qk = p_vo.copy()
    sp = calibrate.absorb_weights(params, cfg, p_qk, p_vo)
    l = 0
    wv = params[f"l{l}.wv"].reshape(d, nkv, dh)
    for j in range(nkv):
        np.testing.assert_allclose(
            sp[f"l{l}.wv_hat"].reshape(d, nkv, dh)[:, j],
            wv[:, j] @ p_vo[l, j], rtol=1e-5, atol=1e-5)
    wo = params[f"l{l}.wo"].reshape(nq, dh, d)
    wo_hat = sp[f"l{l}.wo_hat"].reshape(nq, dh, d)
    for j in range(nq):
        np.testing.assert_allclose(wo_hat[j], p_vo[l, j // g].T @ wo[j],
                                   rtol=1e-5, atol=1e-5)


def test_absorption_identity_projection_is_noop(gqa_setup):
    cfg, params = gqa_setup
    eye = np.broadcast_to(
        np.eye(cfg.d_head, dtype=np.float32),
        (cfg.n_layers, cfg.n_kv_heads, cfg.d_head, cfg.d_head)).copy()
    sp = calibrate.absorb_weights(params, cfg, eye, eye)
    np.testing.assert_allclose(sp["l0.wv_hat"], params["l0.wv"], atol=1e-6)
    np.testing.assert_allclose(sp["l0.wo_hat"], params["l0.wo"], atol=1e-6)


def test_mha_grouping_is_identity():
    """In MHA (G=1) the query grouping must be a plain transpose."""
    cfg = common.NANO_MHA
    assert cfg.group == 1
    params = model.init_params(cfg, seed=5)
    p_qk, p_vo = calibrate.compute_projections(params, cfg, seed=5)
    assert p_qk.shape[1] == cfg.n_q_heads
