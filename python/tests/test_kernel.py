# pytest: Pallas kernels vs pure-jnp oracles — the CORE correctness signal.
# Hypothesis sweeps shapes/dtypes; assert_allclose against ref.py.
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.rotate import rotate
from compile.kernels.swan_attention import swan_attention, swan_attention_heads
from compile.kernels.topk_prune import topk_prune

settings.register_profile("ci", max_examples=12, deadline=None)
settings.load_profile("ci")


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# topk_prune
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 16), d=st.sampled_from([8, 16, 64, 128]),
       frac=st.sampled_from([0.25, 0.5, 0.75, 1.0]), seed=st.integers(0, 2**31))
def test_topk_prune_matches_ref(n, d, frac, seed):
    k = max(1, int(d * frac))
    x = jnp.asarray(_rng(seed).normal(size=(n, d)), jnp.float32)
    vals, idx = topk_prune(x, k)
    rvals, ridx = ref.topk_prune_ref(x, k)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_allclose(np.asarray(vals), np.asarray(rvals))


def test_topk_prune_is_magnitude_descending():
    x = jnp.asarray(_rng(3).normal(size=(5, 32)), jnp.float32)
    vals, _ = topk_prune(x, 8)
    mags = np.abs(np.asarray(vals))
    assert (np.diff(mags, axis=-1) <= 1e-7).all()


def test_topk_prune_full_k_is_permutation():
    x = jnp.asarray(_rng(4).normal(size=(3, 16)), jnp.float32)
    vals, idx = topk_prune(x, 16)
    for r in range(3):
        assert sorted(np.asarray(idx)[r].tolist()) == list(range(16))
        np.testing.assert_allclose(np.sort(np.asarray(vals)[r]),
                                   np.sort(np.asarray(x)[r]))


def test_topk_prune_preserves_signs():
    x = jnp.asarray([[-5.0, 1.0, 4.0, -0.5]], jnp.float32)
    vals, idx = topk_prune(x, 2)
    np.testing.assert_allclose(np.asarray(vals)[0], [-5.0, 4.0])
    np.testing.assert_array_equal(np.asarray(idx)[0], [0, 2])


# ---------------------------------------------------------------------------
# rotate
# ---------------------------------------------------------------------------

@given(n=st.integers(1, 8), d=st.sampled_from([8, 32, 64]), seed=st.integers(0, 2**31))
def test_rotate_matches_ref(n, d, seed):
    r = _rng(seed)
    x = jnp.asarray(r.normal(size=(n, d)), jnp.float32)
    p = jnp.asarray(np.linalg.qr(r.normal(size=(d, d)))[0], jnp.float32)
    np.testing.assert_allclose(np.asarray(rotate(x, p)),
                               np.asarray(ref.rotate_ref(x, p)),
                               rtol=1e-5, atol=1e-5)


def test_rotate_orthogonal_preserves_norm():
    r = _rng(7)
    x = jnp.asarray(r.normal(size=(4, 32)), jnp.float32)
    p = jnp.asarray(np.linalg.qr(r.normal(size=(32, 32)))[0], jnp.float32)
    y = np.asarray(rotate(x, p))
    np.testing.assert_allclose(np.linalg.norm(y, axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)


# ---------------------------------------------------------------------------
# swan_attention
# ---------------------------------------------------------------------------

def _attention_inputs(seed, d=64, ls=24, k=16, b=8, live_s=None, live_b=None):
    r = _rng(seed)
    live_s = ls if live_s is None else live_s
    live_b = b if live_b is None else live_b
    qhat = jnp.asarray(r.normal(size=d), jnp.float32)
    kvals, kidx = ref.topk_prune_ref(jnp.asarray(r.normal(size=(ls, d)), jnp.float32), k)
    vvals, vidx = ref.topk_prune_ref(jnp.asarray(r.normal(size=(ls, d)), jnp.float32), k)
    kbuf = jnp.asarray(r.normal(size=(b, d)), jnp.float32)
    vbuf = jnp.asarray(r.normal(size=(b, d)), jnp.float32)
    smask = jnp.asarray((np.arange(ls) < live_s).astype(np.float32))
    bmask = jnp.asarray((np.arange(b) < live_b).astype(np.float32))
    return qhat, kvals, kidx, vvals, vidx, kbuf, vbuf, smask, bmask


@given(d=st.sampled_from([16, 64, 128]), ls=st.integers(2, 48),
       b=st.integers(1, 16), kfrac=st.sampled_from([0.25, 0.5, 1.0]),
       seed=st.integers(0, 2**31))
def test_swan_attention_matches_ref(d, ls, b, kfrac, seed):
    k = max(1, int(d * kfrac))
    args = _attention_inputs(seed, d=d, ls=ls, k=k, b=b)
    out = swan_attention(*args)
    outr = ref.swan_attention_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(outr),
                               rtol=1e-5, atol=1e-5)


@given(live_s=st.integers(0, 24), live_b=st.integers(1, 8), seed=st.integers(0, 2**31))
def test_swan_attention_respects_masks(live_s, live_b, seed):
    """Padding rows must not influence the output at all."""
    args = list(_attention_inputs(seed, live_s=live_s, live_b=live_b))
    out1 = np.asarray(swan_attention(*args))
    # scribble garbage into masked rows — output must be unchanged
    r = _rng(seed + 1)
    kvals = np.array(args[1], copy=True); kvals[live_s:] = r.normal(size=kvals[live_s:].shape)
    kbuf = np.array(args[5], copy=True); kbuf[live_b:] = r.normal(size=kbuf[live_b:].shape)
    args[1] = jnp.asarray(kvals); args[5] = jnp.asarray(kbuf)
    out2 = np.asarray(swan_attention(*args))
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-6)


def test_swan_attention_full_k_equals_dense():
    """With k_active = d the sparse cache is lossless: hybrid attention must
    equal dense attention over the concatenated cache (Lemma A.1 corollary)."""
    d, ls, b = 32, 12, 4
    r = _rng(11)
    kcache = jnp.asarray(r.normal(size=(ls, d)), jnp.float32)
    vcache = jnp.asarray(r.normal(size=(ls, d)), jnp.float32)
    kbuf = jnp.asarray(r.normal(size=(b, d)), jnp.float32)
    vbuf = jnp.asarray(r.normal(size=(b, d)), jnp.float32)
    qhat = jnp.asarray(r.normal(size=d), jnp.float32)
    kvals, kidx = ref.topk_prune_ref(kcache, d)
    vvals, vidx = ref.topk_prune_ref(vcache, d)
    out = swan_attention(qhat, kvals, kidx, vvals, vidx, kbuf, vbuf,
                         jnp.ones(ls), jnp.ones(b))
    dense = ref.dense_attention_ref(
        qhat, jnp.concatenate([kcache, kbuf]), jnp.concatenate([vcache, vbuf]),
        jnp.ones(ls + b))
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=1e-5, atol=1e-5)


def test_swan_attention_weights_sum_to_one():
    """Uniform values expose the softmax normalisation: if v == const*scatter
    of ones over all dims... simpler: zero sparse values and constant buffer
    values give exactly the buffer-mass fraction."""
    d, ls, b = 16, 8, 4
    qhat = jnp.zeros(d)  # uniform scores
    kvals = jnp.zeros((ls, 2)); kidx = jnp.zeros((ls, 2), jnp.int32)
    vvals = jnp.zeros((ls, 2)); vidx = jnp.zeros((ls, 2), jnp.int32)
    kbuf = jnp.zeros((b, d)); vbuf = jnp.ones((b, d))
    out = np.asarray(swan_attention(qhat, kvals, kidx, vvals, vidx, kbuf, vbuf,
                                    jnp.ones(ls), jnp.ones(b)))
    # all ls+b slots have equal weight; value mass only from buffer
    np.testing.assert_allclose(out, np.full(d, b / (ls + b)), rtol=1e-5)


def test_swan_attention_heads_vmap():
    h, d = 3, 32
    base = [_attention_inputs(s, d=d) for s in range(h)]
    stacked = [jnp.stack([b[i] for b in base]) for i in range(7)]
    out = swan_attention_heads(*stacked, base[0][7], base[0][8])
    for i in range(h):
        args = list(base[i][:7]) + [base[0][7], base[0][8]]
        np.testing.assert_allclose(np.asarray(out[i]),
                                   np.asarray(ref.swan_attention_ref(*args)),
                                   rtol=1e-5, atol=1e-5)
