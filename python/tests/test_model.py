# Model-level shape/semantic tests (dense forward, rope, masks, container IO).
import os
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from compile import common, corpus, model


@pytest.fixture(scope="module")
def gqa():
    cfg = common.NANO_GQA
    params = {k: jnp.asarray(v) for k, v in model.init_params(cfg, 8).items()}
    return cfg, params


def test_dense_forward_shapes(gqa):
    cfg, params = gqa
    logits = model.dense_forward(params, cfg, jnp.zeros(10, jnp.int32))
    assert logits.shape == (10, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_dense_forward_causality(gqa):
    """Changing a future token must not affect earlier logits."""
    cfg, params = gqa
    t1 = jnp.asarray(np.arange(12) % cfg.vocab, jnp.int32)
    t2 = t1.at[-1].set((t1[-1] + 3) % cfg.vocab)
    l1 = np.asarray(model.dense_forward(params, cfg, t1))
    l2 = np.asarray(model.dense_forward(params, cfg, t2))
    np.testing.assert_allclose(l1[:-1], l2[:-1], atol=1e-5)
    assert np.abs(l1[-1] - l2[-1]).max() > 1e-6


def test_rope_position_dependence():
    cfg = common.NANO_GQA
    x = jnp.ones((1, 1, cfg.d_head))
    a0 = model.rope_angles(cfg, jnp.asarray([0]))[:, None, :]
    a5 = model.rope_angles(cfg, jnp.asarray([5]))[:, None, :]
    r0 = np.asarray(model.apply_rope(x, a0))
    r5 = np.asarray(model.apply_rope(x, a5))
    assert np.abs(r0 - r5).max() > 1e-3
    # position 0 is the identity rotation
    np.testing.assert_allclose(r0, np.asarray(x), atol=1e-6)


def test_rope_preserves_norm():
    cfg = common.NANO_GQA
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(3, 2, cfg.d_head)), jnp.float32)
    ang = model.rope_angles(cfg, jnp.asarray([1, 9, 100]))[:, None, :]
    r = np.asarray(model.apply_rope(x, ang))
    np.testing.assert_allclose(np.linalg.norm(r, axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)


def test_rope_relative_property():
    """RoPE scores depend only on relative distance: <R_m q, R_n k> is a
    function of (m - n)."""
    cfg = common.NANO_GQA
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 1, cfg.d_head)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, cfg.d_head)), jnp.float32)

    def score(m, n):
        qm = model.apply_rope(q, model.rope_angles(cfg, jnp.asarray([m]))[:, None, :])
        kn = model.apply_rope(k, model.rope_angles(cfg, jnp.asarray([n]))[:, None, :])
        return float(np.asarray(qm).reshape(-1) @ np.asarray(kn).reshape(-1))

    assert abs(score(3, 1) - score(10, 8)) < 1e-3
    assert abs(score(5, 5) - score(0, 0)) < 1e-3


def test_tokenizer_roundtrip():
    s = "the passkey is 12345 . def f(x): return x + 1"
    ids = common.encode_text(s)
    assert (ids >= 0).all() and (ids < common.VOCAB_SIZE).all()
    assert common.decode_ids(ids) == s


def test_corpus_deterministic_and_alphabet():
    a = corpus.generate_text(5000, seed=3)
    b = corpus.generate_text(5000, seed=3)
    assert a == b
    assert corpus.generate_text(5000, seed=4) != a
    ids = common.encode_text(a)
    assert len(ids) == 5000


def test_tensor_container_roundtrip():
    rng = np.random.default_rng(2)
    tensors = {
        "a": rng.normal(size=(3, 4)).astype(np.float32),
        "b.idx": rng.integers(0, 10, size=(2, 2, 2)).astype(np.int32),
        "scalarish": np.asarray([1.5], np.float32),
    }
    meta = {"name": "t", "n": 3}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.bin")
        common.write_tensors(path, meta, tensors)
        meta2, tensors2 = common.read_tensors(path)
    assert meta2 == meta
    assert set(tensors2) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(tensors2[k], tensors[k])
        assert tensors2[k].dtype == tensors[k].dtype


def test_param_name_orderings():
    cfg = common.NANO_GQA
    params = model.init_params(cfg, 0)
    assert set(common.param_names(cfg)) <= set(params)
    # swan names are disjoint additions except shared tensors
    swan = common.swan_param_names(cfg)
    assert "l0.wv_hat" in swan and "l0.p_qk" in swan
    assert len(swan) == len(set(swan))
