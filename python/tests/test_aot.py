# AOT artifacts: manifest consistency and HLO round-trip (when present).
# These tests run against artifacts/ if `make artifacts` has been run; the
# HLO-generation unit test below runs regardless.
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, common

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_smoke():
    """Lower a trivial jitted fn and sanity-check the HLO text."""
    lowered = jax.jit(lambda x: (x * 2.0 + 1.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_smoke_graph_writer(tmp_path):
    aot.write_smoke_graph(str(tmp_path))
    text = (tmp_path / "model.hlo.txt").read_text()
    assert "HloModule" in text


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first")


@needs_artifacts
def test_manifest_complete():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert set(man["models"]) == {"swan-nano-gqa", "swan-nano-mha"}
    for name, entry in man["models"].items():
        assert os.path.exists(os.path.join(ART, entry["weights"]))
        assert os.path.exists(os.path.join(ART, entry["golden"]))
        for g, ginfo in entry["graphs"].items():
            assert os.path.exists(os.path.join(ART, ginfo["file"])), g
        # every bucket combination present
        for t in man["prefill_t"]:
            assert f"prefill_t{t}" in entry["graphs"]
        for ls in man["decode_l"]:
            for k in man["decode_k"]:
                assert f"decode_l{ls}_k{k}" in entry["graphs"]


@needs_artifacts
def test_weights_container_contents():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for name, entry in man["models"].items():
        cfg = common.CONFIGS[name]
        meta, tensors = common.read_tensors(os.path.join(ART, entry["weights"]))
        assert meta["name"] == name
        for n in common.param_names(cfg):
            assert n in tensors, n
        for n in common.swan_param_names(cfg):
            assert n in tensors, n
        # projections orthogonal
        p = tensors["l0.p_qk"]
        eye = np.eye(cfg.d_head)
        np.testing.assert_allclose(p[0] @ p[0].T, eye, atol=1e-4)


@needs_artifacts
def test_golden_losslessness_recorded():
    """The stored goldens must themselves satisfy Lemma A.2: swan prefill
    logits == dense logits at the last prompt position."""
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for name, entry in man["models"].items():
        _, g = common.read_tensors(os.path.join(ART, entry["golden"]))
        np.testing.assert_allclose(g["prefill_logits"],
                                   g["dense_logits"][-1], rtol=5e-3, atol=5e-3)


@needs_artifacts
def test_trained_model_beats_chance():
    """End-to-end training evidence: held-out corpus perplexity must be far
    below the uniform baseline (ln 96 ≈ 4.56)."""
    from compile import corpus, model
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    entry = man["models"]["swan-nano-gqa"]
    cfg = common.CONFIGS["swan-nano-gqa"]
    _, tensors = common.read_tensors(os.path.join(ART, entry["weights"]))
    params = {n: jnp.asarray(tensors[n]) for n in common.param_names(cfg)}
    text = corpus.generate_text(2000, seed=1234)  # unseen seed
    ids = common.encode_text(text)[:256]
    logits = model.dense_forward(params, cfg, jnp.asarray(ids[:-1]))
    logp = jax.nn.log_softmax(logits)
    nll = -np.take_along_axis(np.asarray(logp), ids[1:, None], axis=-1).mean()
    assert nll < 3.0, f"trained model nll {nll} not better than chance 4.56"
